"""Disaggregated front end: prefill pool + decode pool, one clock discipline.

:class:`DisaggFrontEnd` mirrors the multi-replica
:class:`~repro.router.frontend.FrontEnd` API (``submit`` / ``step`` /
``step_until`` / ``drain`` / ``stats`` / ``aggregate`` / ``replay``) but
splits every request across two pools connected by a **handoff queue**:

1. ``submit()`` parses the same OpenAI-style dict
   (:func:`~repro.router.frontend.parse_request`), pre-checks decode KV
   capacity, sheds when the handoff queue is at ``max_handoff_depth``
   (``reason="handoff_overload"``), and enqueues a
   :class:`~repro.disagg.ticket.PrefillTicket` on the least-loaded
   prefill engine.
2. The **lockstep loop** always steps the laggard unit — the prefill
   engine or decode session with the earliest next event on its modeled
   clock — so handoffs happen at contemporaneous times and neither pool
   races ahead of the other's clock.
3. After every step the **pump** moves READY tickets across the boundary:
   the published chain is resolved *by reference*
   (``PrefixCache.chain_metas(ticket.chain_head)``) and checksum-verified
   (``verify_chain``) **before** any decode session sees the request.  A
   broken or corrupt chain is quarantined and the ticket re-queued for
   re-prefill (arrival = its ready time, so the retry pays queueing
   honestly), bounded by ``max_prefill_attempts``; on exhaustion the
   ticket fails terminally.  A decode row is therefore *never* admitted
   from a quarantined chain.
4. Decode sessions are plain :class:`~repro.serving.api.ServeSession`\\ s
   sharing the prefill pool's :class:`~repro.cache.PrefixCache`: their
   admission restores the published chain (the warm-prefill path), so the
   decode clock pays restore I/O instead of prefill compute — which is
   the whole point of the split.

Bit-identity: the decode session recomputes admission logits from the
restored prefix exactly as a cold prefill would (the cache's restore
contract at ``kv_bits=16``), and a request's token stream depends only on
its own prompt + sampling — so disaggregated tokens equal co-located and
solo tokens per request.  ``benchmarks/disagg_serving.py`` asserts this.
"""

from __future__ import annotations

import collections
from typing import Mapping

import numpy as np

from repro.disagg.prefill import PrefillEngine
from repro.disagg.ticket import (ADMITTED, DONE, FAILED, QUEUED, READY,
                                 PrefillTicket)
from repro.obs import NULL_OBS
from repro.router.frontend import parse_request
from repro.serving.api import ServeSession
from repro.serving.errors import RequestRejected
from repro.serving.metrics import aggregate_requests, request_record

__all__ = ["DisaggFrontEnd"]


class DisaggFrontEnd:
    """Schedule a prefill pool and a decode pool in modeled-clock lockstep.

    ``max_handoff_depth`` bounds the READY-ticket queue at admission time
    (router-tier shedding, pure bookkeeping); ``max_prefill_attempts``
    bounds the corrupt-chain re-prefill ladder per ticket.
    """

    def __init__(self, prefills: list[PrefillEngine],
                 decodes: list[ServeSession], *, cache,
                 max_handoff_depth: int | None = None,
                 max_prefill_attempts: int = 3, obs=None):
        if not prefills or not decodes:
            raise ValueError("need at least one prefill engine and one "
                             "decode session")
        if max_handoff_depth is not None and max_handoff_depth < 1:
            raise ValueError("max_handoff_depth must be >= 1 (or None)")
        if max_prefill_attempts < 1:
            raise ValueError("max_prefill_attempts must be >= 1")
        self.prefills = list(prefills)
        self.decodes = list(decodes)
        self._decode_names = [f"d{i}" for i in range(len(decodes))]
        self.cache = cache
        self.max_handoff_depth = max_handoff_depth
        self.max_prefill_attempts = max_prefill_attempts
        self.obs = obs if obs is not None else NULL_OBS
        self.handoff: collections.deque[PrefillTicket] = collections.deque()
        self.tickets: dict[int, PrefillTicket] = {}
        self.handoff_rejections = 0     # shed at submit (handoff_overload)
        self.requeues = 0               # corrupt-chain re-prefills
        self.ticket_failures = 0        # terminal ticket failures
        self.max_handoff_seen = 0       # high-water mark of READY tickets
        self._rid = 0

    # -- obs helpers ------------------------------------------------------
    def _count(self, name: str, help: str, delta: float = 1,
               **labels) -> None:
        if self.obs.enabled:
            self.obs.registry.counter(name, help, labels=labels).inc(delta)

    # -- admission --------------------------------------------------------
    def submit(self, request: Mapping) -> int:
        """Queue one request for prefill; returns its global id.

        Raises :class:`RequestRejected` with ``reason="capacity"`` when
        the prompt could never fit a decode engine, or
        ``reason="handoff_overload"`` when the handoff queue is at its
        bound — both before any engine is touched."""
        prompt, max_new, kw = parse_request(request)
        cap = min(d.engine.cap_tokens for d in self.decodes)
        if len(prompt) + max_new > cap:
            self._count("kvswap_disagg_rejections_total",
                        "disagg front-end shed submissions",
                        reason="capacity")
            raise RequestRejected(
                "capacity",
                f"prompt ({len(prompt)}) + max_new ({max_new}) exceeds the "
                f"decode pool's KV capacity ({cap} tokens)",
                prompt_tokens=len(prompt), max_new=max_new, cap_tokens=cap)
        if self.max_handoff_depth is not None \
                and len(self.handoff) >= self.max_handoff_depth:
            self.handoff_rejections += 1
            self._count("kvswap_disagg_rejections_total",
                        "disagg front-end shed submissions",
                        reason="handoff_overload")
            raise RequestRejected(
                "handoff_overload",
                f"handoff queue is at max_handoff_depth="
                f"{self.max_handoff_depth}; decode pool is behind",
                max_handoff_depth=self.max_handoff_depth,
                queued=len(self.handoff))
        rid = self._rid
        self._rid += 1
        arrival = kw["arrival"] if kw["arrival"] is not None else 0.0
        ticket = PrefillTicket(
            rid=rid, prompt=prompt, max_new=max_new,
            stop_ids=kw["stop_ids"], sampling=kw["sampling"],
            arrival=arrival, submitted_at=arrival,
            slo_class=kw["slo_class"], tenant=kw["tenant"])
        self.tickets[rid] = ticket
        self._assign(ticket)
        self._count("kvswap_disagg_tickets_total",
                    "tickets submitted to the prefill pool")
        return rid

    def _assign(self, ticket: PrefillTicket) -> None:
        """Least-queued prefill engine, ties to pool order."""
        eng = min(self.prefills, key=lambda e: (len(e.queue), e.name))
        eng.enqueue(ticket)

    # -- the handoff pump --------------------------------------------------
    def _verify(self, ticket: PrefillTicket) -> bool:
        """Chain integrity at the boundary.  True = safe to hand to
        decode.  A broken handle (evicted/quarantined ancestor) or a
        checksum mismatch (which quarantines, exactly like a restore
        would) is False — the decode pool never sees the ticket."""
        if ticket.chain_head is None:
            return True     # nothing published: decode admits cold
        metas = self.cache.chain_metas(ticket.chain_head)
        if metas is None:
            return False
        return self.cache.verify_chain(metas)

    def _requeue(self, ticket: PrefillTicket) -> None:
        """Corrupt chain at handoff: bounded re-prefill, or terminal."""
        if ticket.attempts >= self.max_prefill_attempts:
            ticket.state = FAILED
            ticket.error = (f"chain {ticket.chain_head} corrupt at handoff "
                            f"after {ticket.attempts} prefill attempt(s)")
            self.ticket_failures += 1
            self._count("kvswap_disagg_ticket_failures_total",
                        "tickets failed terminally", reason="corrupt_chain")
            if self.obs.enabled:
                self.obs.tracer.add(
                    f"r{ticket.rid} failed", "handoff", cat="disagg",
                    model_t0=ticket.ready_time, instant=True,
                    args={"rid": ticket.rid, "error": ticket.error})
            return
        self.requeues += 1
        self._count("kvswap_disagg_requeues_total",
                    "tickets re-queued for re-prefill (corrupt chain)")
        if self.obs.enabled:
            self.obs.tracer.add(
                f"r{ticket.rid} requeue", "handoff", cat="disagg",
                model_t0=ticket.ready_time, instant=True,
                args={"rid": ticket.rid, "attempt": ticket.attempts,
                      "chain_head": ticket.chain_head or ""})
        # the retry arrives when the corruption was discovered — queueing
        # time is honest, and the re-prefill's restore path reuses any
        # ancestors that survived the quarantine
        ticket.arrival = float(ticket.ready_time)
        ticket.chain_head = None
        ticket.ready_time = None
        self._assign(ticket)

    def _pump(self) -> None:
        """Drain the handoff queue into decode sessions (FIFO by ready
        time).  Each ticket is verified first; survivors are submitted to
        the least-loaded decode session with ``arrival=ready_time`` so the
        decode clock honors the prefill pool's completion times."""
        self.max_handoff_seen = max(self.max_handoff_seen, len(self.handoff))
        while self.handoff:
            ticket = self.handoff.popleft()
            if ticket.state is not READY:
                continue
            if not self._verify(ticket):
                self._requeue(ticket)
                continue
            di = min(range(len(self.decodes)),
                     key=lambda i: (self.decodes[i].queue_depth
                                    + self.decodes[i].active_rows, i))
            ds = self.decodes[di]
            try:
                local = ds.submit(
                    ticket.prompt, ticket.max_new,
                    stop_ids=ticket.stop_ids, sampling=ticket.sampling,
                    sampler=ticket.sampler, arrival=ticket.ready_time,
                    slo_class=ticket.slo_class, tenant=ticket.tenant)
            except RequestRejected as exc:
                # the decode tier refused (overload shedding mid-incident);
                # terminal — retrying would deadlock drain on a session
                # that keeps saying no
                ticket.state = FAILED
                ticket.error = f"decode rejected: {exc.reason}"
                self.ticket_failures += 1
                self._count("kvswap_disagg_ticket_failures_total",
                            "tickets failed terminally",
                            reason="decode_rejected")
                continue
            ticket.state = ADMITTED
            ticket.decode_name = self._decode_names[di]
            ticket.decode_rid = local
            if self.obs.enabled:
                self.obs.tracer.add(
                    f"r{ticket.rid} handoff", "handoff", cat="disagg",
                    model_t0=ticket.ready_time, instant=True,
                    args={"rid": ticket.rid, "decode": ticket.decode_name,
                          "chain_head": ticket.chain_head or "",
                          "cached_tokens":
                              ticket.prefill_report.get("cached_tokens", 0)})

    # -- the lockstep scheduler loop --------------------------------------
    def _decode_next_time(self, ds: ServeSession) -> float:
        """A decode session's next event time: its clock while rows run,
        else the earliest waiting arrival (the session's own idle-jump),
        else ``inf``."""
        if ds.active_rows:
            return ds.now
        if ds.queue_depth:
            return max(ds.now, min(r.arrival for r in ds._waiting))
        return float("inf")

    def _units(self) -> list[tuple[float, int, str, object]]:
        """Steppable units ordered (next_time, pool order) — prefill
        engines before decode sessions on exact ties, so a handoff
        produced at time T is pumped before the decode pool steps past
        T."""
        units: list[tuple[float, int, str, object]] = []
        for i, pe in enumerate(self.prefills):
            if pe.has_work:
                units.append((pe.next_time, i, "prefill", pe))
        off = len(self.prefills)
        for i, ds in enumerate(self.decodes):
            if ds.has_work:
                units.append((self._decode_next_time(ds), off + i,
                              "decode", ds))
        units.sort(key=lambda u: (u[0], u[1]))
        return units

    def step(self) -> list[dict]:
        """One lockstep iteration: step the laggard unit, then pump the
        handoff queue.  Returns that unit's events, each stamped with a
        ``"phase"`` key; an idle system returns ``[]``."""
        units = self._units()
        if not units:
            return []
        _, _, phase, unit = units[0]
        events: list[dict] = []
        if phase == "prefill":
            ticket = unit.step()
            if ticket is not None:
                if ticket.state is READY:
                    self.handoff.append(ticket)
                    events.append({"type": "prefill_done", "rid": ticket.rid,
                                   "engine": unit.name, "t": ticket.ready_time,
                                   "attempt": ticket.attempts,
                                   "chain_head": ticket.chain_head})
                else:   # admission storage fault: terminal
                    self.ticket_failures += 1
                    self._count("kvswap_disagg_ticket_failures_total",
                                "tickets failed terminally",
                                reason="prefill_fault")
                    events.append({"type": "prefill_fail", "rid": ticket.rid,
                                   "engine": unit.name, "t": unit.now,
                                   "error": ticket.error})
        else:
            for ev in unit.step():
                ev["phase"] = "decode"
                events.append(ev)
        self._pump()
        return events

    def step_until(self, t: float) -> list[dict]:
        """Advance every unit whose next event is before ``t`` (the replay
        loop's synchronizer — arrivals are routed against contemporaneous
        queue-depth signals)."""
        events: list[dict] = []
        while True:
            units = [u for u in self._units() if u[0] < t]
            if not units:
                return events
            events.extend(self.step())

    @property
    def has_work(self) -> bool:
        return (any(pe.has_work for pe in self.prefills)
                or bool(self.handoff)
                or any(ds.has_work for ds in self.decodes))

    def drain(self) -> dict[int, np.ndarray]:
        """Run both pools to completion; persists the shared cache's
        manifest once, then returns completed tokens by global id."""
        while self.has_work:
            if not self.step() and self.handoff:
                self._pump()    # only READY tickets left: flush them
        self.cache.save()
        return self.results()

    # -- results ----------------------------------------------------------
    def _completed(self, rid: int):
        ticket = self.tickets[rid]
        if ticket.decode_rid is None:
            return None
        ds = self.decodes[self._decode_names.index(ticket.decode_name)]
        req = ds.completed.get(ticket.decode_rid)
        if req is not None:
            ticket.state = DONE
        return req

    def results(self) -> dict[int, np.ndarray]:
        out = {}
        for rid in self.tickets:
            req = self._completed(rid)
            if req is not None:
                out[rid] = req.output
        return out

    def result(self, rid: int) -> np.ndarray:
        req = self._completed(rid)
        if req is None:
            raise KeyError(f"request {rid} has not completed")
        return req.output

    # -- stats ------------------------------------------------------------
    def stats(self) -> dict:
        """Two-pool view: per-unit snapshots plus cross-pool totals.
        ``makespan_s`` is the max clock across both pools; rates are
        recomputed from summed numerators/denominators."""
        prefill = [pe.stats() for pe in self.prefills]
        decode = {name: ds.stats() for name, ds
                  in zip(self._decode_names, self.decodes)}
        sessions = list(decode.values())

        def total(key):
            return sum(s[key] for s in sessions)

        makespan = max([p["now"] for p in prefill]
                       + [ds.now for ds in self.decodes] + [0.0])
        tokens = total("completed_tokens")
        prompt_tokens = total("prompt_tokens")
        cached = total("cached_prompt_tokens")
        return {
            "prefill_engines": prefill,
            "decode_sessions": decode,
            "n_prefill": len(self.prefills),
            "n_decode": len(self.decodes),
            "completed_requests": total("completed_requests"),
            "completed_tokens": tokens,
            "failed_requests": total("failed_requests"),
            "ticket_failures": self.ticket_failures,
            "handoff_rejections": self.handoff_rejections,
            "requeues": self.requeues,
            "max_handoff_depth_seen": self.max_handoff_seen,
            "handoff_pending": len(self.handoff),
            "prefill_published_blocks":
                sum(p["published_blocks"] for p in prefill),
            "makespan_s": makespan,
            "goodput_tokens_per_s": tokens / makespan if makespan else 0.0,
            "prompt_tokens": prompt_tokens,
            "cached_prompt_tokens": cached,
            "prefix_hit_rate": (cached / prompt_tokens
                                if prompt_tokens else 0.0),
        }

    def aggregate(self, slo_classes: Mapping) -> dict:
        """Per-request SLO aggregation across the decode pool, re-stamped
        with global rids.  End-to-end latency is corrected back to the
        *original* arrival (the decode request's arrival is the ticket's
        ready time, so prefill + handoff time would otherwise vanish);
        TTFT/TPOT stay decode-side by construction."""
        records = []
        for rid in sorted(self.tickets):
            ticket = self.tickets[rid]
            req = self._completed(rid)
            if req is None:
                continue
            rec = request_record(req)
            rec["rid"] = rid
            rec["prefill_engine"] = ticket.prefill_engine
            rec["decode"] = ticket.decode_name
            rec["prefill_attempts"] = ticket.attempts
            rec["e2e_seconds"] += float(ticket.ready_time) \
                - ticket.submitted_at
            records.append(rec)
        makespan = max([pe.now for pe in self.prefills]
                       + [ds.now for ds in self.decodes] + [0.0])
        agg = aggregate_requests(records, slo_classes, makespan_s=makespan)
        return {**agg, "per_request": records}

    # -- trace replay ------------------------------------------------------
    def replay(self, trace) -> dict:
        """Drive a :class:`~repro.serving.trace.Trace` through the split
        stack as-it-arrives; shed submissions are part of the measurement.
        Returns the SLO aggregation plus :meth:`stats` under ``"fleet"``.
        """
        for r in trace.requests:
            self.step_until(r.arrival)
            try:
                self.submit({"prompt": r.materialize(trace.vocab_size),
                             "max_new": r.max_new, "arrival": r.arrival,
                             "slo_class": r.slo_class, "tenant": r.tenant})
            except RequestRejected:
                pass
        self.drain()
        agg = self.aggregate(trace.slo_classes)
        return {**agg, "fleet": self.stats()}

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        for pe in self.prefills:
            pe.close()
        for ds in self.decodes:
            ds.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
