"""Asynchronous disk-I/O subsystem for the KVSwap runtime (§3.3–§3.4).

- :mod:`repro.io.scheduler` — sort/coalesce group reads into sequential runs;
- :mod:`repro.io.prefetch` — background worker pool + double buffer that
  overlap layer *i+1*'s group preloading with layer *i*'s compute.
"""

from repro.io.prefetch import (DoubleBuffer, PrefetchQueueFull, PrefetchResult,
                               PrefetchWorker)
from repro.io.scheduler import ReadRun, ReadScheduler

__all__ = [
    "DoubleBuffer",
    "PrefetchQueueFull",
    "PrefetchResult",
    "PrefetchWorker",
    "ReadRun",
    "ReadScheduler",
]
