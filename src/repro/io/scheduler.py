"""Read scheduling for the disk tier (KVSwap §3.4.4).

The predictor emits an unordered set of group ids; the device wants few,
large, sequential requests (Fig. 2: effective bandwidth collapses below ~6 %
of peak for small random reads).  :class:`ReadScheduler` turns a miss list
into an ordered *plan* of coalesced runs:

1. sort and de-duplicate the requested group ids,
2. merge **adjacent** ids into one contiguous run (one sequential read),
3. optionally read *through* small gaps (``max_gap`` groups) when streaming
   the gap bytes is cheaper than paying another per-request latency — the
   classic elevator/deadline trade on NAND storage.

The scheduler is pure (no I/O, no locks): it only plans.  ``KVDiskStore``
executes runs via :meth:`~repro.core.offload.KVDiskStore.read_run`, charging
the :class:`~repro.core.offload.IOAccountant` one request per run.  Purity
extends to observability: the scheduler publishes nothing itself — callers
(:class:`~repro.core.manager.KVCacheManager`) feed :meth:`ReadScheduler.
stats` of each plan into the metrics registry (``kvswap_read_plan_*``), so
planning stays trivially unit-testable.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence


@dataclasses.dataclass(frozen=True)
class ReadRun:
    """One contiguous disk request covering ``[start, start + count)`` groups.

    ``ids`` are the *requested* group ids inside the run (sorted).  When gap
    coalescing is on, ``count`` may exceed ``len(ids)``: the extra groups are
    read and discarded, trading bytes for requests.
    """

    start: int
    count: int
    ids: tuple[int, ...]

    @property
    def stop(self) -> int:
        return self.start + self.count

    def waste(self) -> int:
        """Number of gap groups read but not requested."""
        return self.count - len(self.ids)


class ReadScheduler:
    """Sort + coalesce group-read requests into large sequential runs.

    ``max_gap`` is the largest run of *unrequested* groups the scheduler will
    read through to keep a request sequential.  ``max_gap=0`` (default)
    merges only strictly adjacent ids — byte counts then exactly equal the
    requested payload, which is what the accounting tests pin down.
    """

    def __init__(self, max_gap: int = 0):
        if max_gap < 0:
            raise ValueError(f"max_gap must be >= 0, got {max_gap}")
        self.max_gap = max_gap

    @classmethod
    def from_spec(cls, spec, group_nbytes: int) -> "ReadScheduler":
        """Pick ``max_gap`` from device characteristics: reading a gap group
        is worthwhile while its streaming time stays under the per-request
        latency (``gap · group_nbytes / peak_bw < request_latency``)."""
        if group_nbytes <= 0:
            return cls(0)
        max_gap = int(spec.request_latency * spec.peak_bw // group_nbytes)
        return cls(max_gap=max_gap)

    def plan(self, group_ids: Iterable[int]) -> list[ReadRun]:
        """Plan coalesced runs for a set of group ids (any order, dups ok)."""
        ids = sorted({int(g) for g in group_ids})
        if not ids:
            return []
        runs: list[ReadRun] = []
        run_start = ids[0]
        run_ids = [ids[0]]
        for g in ids[1:]:
            gap = g - run_ids[-1] - 1
            if gap <= self.max_gap:
                run_ids.append(g)
            else:
                runs.append(ReadRun(run_start, run_ids[-1] - run_start + 1,
                                    tuple(run_ids)))
                run_start = g
                run_ids = [g]
        runs.append(ReadRun(run_start, run_ids[-1] - run_start + 1, tuple(run_ids)))
        return runs

    def stats(self, plan: Sequence[ReadRun]) -> dict:
        """Summary counters for a plan (used by tests and benchmarks)."""
        return {
            "requests": len(plan),
            "groups_requested": sum(len(r.ids) for r in plan),
            "groups_read": sum(r.count for r in plan),
            "groups_wasted": sum(r.waste() for r in plan),
        }
