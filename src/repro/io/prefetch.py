"""Asynchronous group preloading (KVSwap §3.3–§3.4).

The paper's pipeline issues the disk reads for layer *i+1*'s predicted
critical groups while layer *i* computes, so I/O time hides under compute.
Two pieces implement that here:

* :class:`PrefetchWorker` — a small thread pool servicing group-read requests
  from **per-layer queues**.  Per-layer queuing is a correctness property,
  not an optimization: a fetch mutates that layer's reuse buffer, so two
  requests for the same layer must never run concurrently.  Requests across
  layers are drained FIFO by submission order.

* :class:`DoubleBuffer` — the front/back staging area between the engine and
  the worker.  While layer *i* computes against the *front* result, layer
  *i+1*'s request is in flight in the *back* slot; reaching layer *i+1*
  rotates the back to the front (blocking only if the read hasn't landed).

The worker runs host-side code only (numpy + memmap); all JAX compute stays
on the caller's thread.  Modeled I/O time per request is captured with
``IOAccountant.track()`` so the engine can report both the *modeled* overlap
(DiskSpec seconds) and the *measured* one (wall-clock seconds).
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time
from concurrent.futures import Future
from typing import Callable

__all__ = [
    "DoubleBuffer",
    "PrefetchQueueFull",
    "PrefetchResult",
    "PrefetchWorker",
]


class PrefetchQueueFull(RuntimeError):
    """Raised by ``submit(block=False)`` when the pending queue is at capacity."""


@dataclasses.dataclass
class PrefetchResult:
    """What a serviced request returns: the payload plus its I/O cost."""

    table: object            # whatever fetch_fn produced (engine: MappingTable)
    io_seconds: float = 0.0  # modeled serve time of this fetch (disk + warm tier)
    io_bytes: int = 0        # disk bytes read
    io_requests: int = 0
    wall_seconds: float = 0.0  # measured service time on the worker thread


@dataclasses.dataclass
class _Request:
    seq: int
    layer: int
    args: tuple
    future: Future


class PrefetchWorker:
    """Thread pool draining per-layer queues of group-read requests.

    ``fetch_fn(layer, *args)`` runs on a worker thread and must only touch
    host memory (the engine passes ``managers[layer].fetch``).  ``submit``
    returns a :class:`concurrent.futures.Future` resolving to a
    :class:`PrefetchResult`.

    Invariants:

    * at most one in-flight request per layer (queued requests for a busy
      layer wait until it frees up);
    * across layers, the oldest submitted request is serviced first;
    * at most ``max_pending`` requests queued; ``submit`` blocks (or raises
      :class:`PrefetchQueueFull` with ``block=False``) beyond that;
    * ``close()`` cancels queued requests, lets in-flight ones finish, and
      joins the threads;
    * **worker threads survive every request failure** (docs/robustness.md):
      a raised fetch resolves only *that* request's future — the original
      exception object, enriched with ``prefetch_layer``/``prefetch_args``
      context — and the thread goes back to the queue.  ``deaths`` counts
      threads lost to failures outside any request (should stay 0) and
      ``dropped_errors`` counts exceptions that had no live future left to
      carry them (consumer cancelled first).
    """

    def __init__(
        self,
        fetch_fn: Callable,
        *,
        n_threads: int = 2,
        max_pending: int = 64,
        accountant=None,
        name: str = "kvswap-prefetch",
        obs=None,
    ):
        if n_threads < 1:
            raise ValueError("need at least one worker thread")
        if max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        self._fetch_fn = fetch_fn
        self._accountant = accountant
        # observability: each worker thread records its serviced fetches as
        # wall spans on its own lane (the thread's name), which is where the
        # measured overlap — worker lanes busy under the engine lane — shows
        self._obs = obs
        self.max_pending = max_pending
        self._cv = threading.Condition()
        self._pending: dict[int, collections.deque] = {}
        self._active: set[int] = set()
        self._n_pending = 0
        self._seq = itertools.count()
        self._shutdown = False
        self.serviced = 0
        self.deaths = 0         # worker threads lost outside a request
        self.dropped_errors = 0  # failures with no live future to carry them
        self._threads = [
            threading.Thread(target=self._run, name=f"{name}-{i}", daemon=True)
            for i in range(n_threads)
        ]
        for t in self._threads:
            t.start()

    # -- producer side ----------------------------------------------------
    def submit(self, layer: int, *args, block: bool = True,
               timeout: float | None = None) -> Future:
        """Enqueue a read for ``layer``; returns a Future[PrefetchResult]."""
        fut: Future = Future()
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            if self._shutdown:
                raise RuntimeError("PrefetchWorker is shut down")
            while self._n_pending >= self.max_pending:
                if not block:
                    raise PrefetchQueueFull(
                        f"{self._n_pending} requests pending (cap {self.max_pending})")
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise PrefetchQueueFull(f"queue still full after {timeout}s")
                self._cv.wait(timeout=remaining)
                if self._shutdown:
                    raise RuntimeError("PrefetchWorker is shut down")
            req = _Request(next(self._seq), int(layer), args, fut)
            self._pending.setdefault(req.layer, collections.deque()).append(req)
            self._n_pending += 1
            self._cv.notify_all()
        return fut

    @property
    def pending_count(self) -> int:
        with self._cv:
            return self._n_pending + len(self._active)

    # -- worker side ------------------------------------------------------
    def _pick(self) -> _Request | None:
        """Oldest pending request whose layer is idle.  Caller holds _cv."""
        best: _Request | None = None
        for layer, dq in self._pending.items():
            if not dq or layer in self._active:
                continue
            if best is None or dq[0].seq < best.seq:
                best = dq[0]
        return best

    def _run(self) -> None:
        try:
            while True:
                with self._cv:
                    req = self._pick()
                    while req is None:
                        if self._shutdown:
                            return
                        self._cv.wait()
                        req = self._pick()
                    self._pending[req.layer].popleft()
                    self._n_pending -= 1
                    self._active.add(req.layer)
                    self._cv.notify_all()
                self._serve(req)
        except BaseException:
            # nothing in _serve lets an exception out, so only queue
            # bookkeeping can land here; count the death so harnesses can
            # assert it never happens, then re-raise for the traceback
            with self._cv:
                self.deaths += 1
                self._cv.notify_all()
            raise

    def _serve(self, req: _Request) -> None:
        """Service one request.  Never raises: success and failure both
        resolve ``req.future``, and the worker thread lives on either way
        (a dead worker would silently serialize every later layer)."""
        ok = False
        try:
            if not req.future.set_running_or_notify_cancel():
                return  # consumer cancelled while queued
            t0 = time.perf_counter()
            if self._accountant is not None:
                with self._accountant.track() as tr:
                    table = self._fetch_fn(req.layer, *req.args)
                res = PrefetchResult(
                    table=table,
                    io_seconds=tr.read_seconds + tr.warm_seconds,
                    io_bytes=tr.read_bytes, io_requests=tr.read_requests,
                    wall_seconds=time.perf_counter() - t0)
            else:
                table = self._fetch_fn(req.layer, *req.args)
                res = PrefetchResult(
                    table=table, wall_seconds=time.perf_counter() - t0)
            obs = self._obs
            if obs is not None and obs.enabled:
                obs.tracer.add(
                    f"fetch L{req.layer}",
                    threading.current_thread().name, cat="prefetch",
                    wall_t0=obs.tracer.now_wall() - res.wall_seconds,
                    wall_dur=res.wall_seconds,
                    args={"layer": req.layer,
                          "modeled_io_s": res.io_seconds,
                          "read_bytes": res.io_bytes})
            req.future.set_result(res)
            ok = True
        except BaseException as exc:  # propagate to the consumer
            # surface the *original* exception (callers match on its type)
            # enriched with request context; some exception types forbid
            # new attributes, hence the guard
            try:
                exc.prefetch_layer = req.layer
                exc.prefetch_args = req.args
            except (AttributeError, TypeError):
                pass
            try:
                req.future.set_exception(exc)
            except BaseException:
                # future already cancelled/completed — the error has no
                # consumer; count it instead of killing the thread
                with self._cv:
                    self.dropped_errors += 1
        finally:
            with self._cv:
                if ok:
                    self.serviced += 1
                self._active.discard(req.layer)
                self._cv.notify_all()

    def alive_threads(self) -> int:
        """Worker threads still running (harness assertion helper)."""
        return sum(1 for t in self._threads if t.is_alive())

    # -- lifecycle --------------------------------------------------------
    def close(self, *, wait: bool = True, timeout: float = 10.0) -> None:
        """Cancel queued requests, finish in-flight ones, join the pool."""
        with self._cv:
            if self._shutdown:
                return
            self._shutdown = True
            leftovers = [r for dq in self._pending.values() for r in dq]
            self._pending.clear()
            self._n_pending = 0
            self._cv.notify_all()
        for req in leftovers:
            req.future.cancel()
        if wait:
            deadline = time.perf_counter() + timeout
            for t in self._threads:
                t.join(max(0.0, deadline - time.perf_counter()))

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class DoubleBuffer:
    """Front/back staging of per-layer prefetch futures.

    In steady state exactly two results are live: the *front* (layer *i*'s
    table, being consumed by compute) and the *back* (layer *i+1*'s read, in
    flight).  ``stage`` files the back slot; ``take`` rotates it to the front
    when compute reaches that layer, blocking only on an I/O-bound step.
    ``depth`` guards against the engine leaking slots (a staged result that
    is never taken).
    """

    def __init__(self, depth: int = 2):
        self.depth = depth
        self._slots: dict[int, Future] = {}
        self.drained_errors = 0

    def stage(self, key: int, future: Future) -> None:
        if key in self._slots:
            raise RuntimeError(f"slot {key} already staged")
        if len(self._slots) >= self.depth:
            raise RuntimeError(
                f"double buffer over depth {self.depth}: {sorted(self._slots)}")
        self._slots[key] = future

    def take(self, key: int, timeout: float | None = None) -> PrefetchResult:
        fut = self._slots.pop(key)
        return fut.result(timeout=timeout)

    def pending(self) -> int:
        return len(self._slots)

    def drain(self) -> int:
        """Wait out / discard staged results (error-path cleanup).

        Returns how many discarded results carried an exception (also
        accumulated on ``drained_errors``) so error paths can report what
        they threw away instead of swallowing it silently.  Only
        ``Exception`` is absorbed — ``KeyboardInterrupt``/``SystemExit``
        still propagate.
        """
        errors = 0
        for key in sorted(self._slots):
            fut = self._slots.pop(key)
            if not fut.cancel():
                try:
                    fut.result()
                except Exception:
                    errors += 1
        self.drained_errors += errors
        return errors
