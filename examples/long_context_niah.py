"""Needle-in-a-haystack through the real engine (paper Fig. 9 demo).

Plants an induction-pattern needle in a long prompt, serves it through the
disk-backed engine at several (depth × budget) points, and reports whether
the needle's KV groups were selected at decode time.

    PYTHONPATH=src python examples/long_context_niah.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.core.engine import EngineConfig, KVSwapEngine
from repro.data import SyntheticLMStream, make_needle_prompt
from repro.models.transformer import (ModelConfig, TransformerAdapter, forward,
                                      init_params)
from repro.training.optim import AdamWConfig
from repro.training.train import train_loop


def main() -> None:
    cfg = ModelConfig(name="niah", arch_type="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=97)
    params = init_params(jax.random.PRNGKey(0), cfg)
    stream = SyntheticLMStream(cfg.vocab_size, seed=11, copy_prob=0.25)
    state, _ = train_loop(params, forward, cfg, stream, steps=120, batch=8,
                          seq_len=64, opt_cfg=AdamWConfig(lr=3e-3), log_every=40)
    params = state.params
    adapter_model = TransformerAdapter(cfg)
    rng = np.random.default_rng(0)
    calib = rng.standard_normal((256, cfg.n_kv_heads, cfg.head_dim))

    print("depth  selected_needle_groups / total_needle_groups")
    for depth in (0.1, 0.3, 0.5, 0.7, 0.9):
        task = make_needle_prompt(cfg.vocab_size, 96, depth=depth, seed=5)
        prompt = task.tokens[None, :]
        ecfg = EngineConfig(group_size=4, n_select=8, rank=16,
                            reuse_capacity=16, max_seq=160)
        with KVSwapEngine(adapter_model, params, ecfg, batch=1, calib_k=calib) as eng:
            eng.prefill(prompt)
            eng.decode_step(np.asarray([task.tokens[-1]]))
            # inspect what the managers actually fetched this step
            needle_groups = {p // ecfg.group_size for p in task.needle_span}
            seen = set()
            for reuse in eng.reuse:
                for bi in range(1):
                    seen |= reuse.resident(bi)
            hit = len(needle_groups & seen)
            print(f"{depth:5.1f}  {hit} / {len(needle_groups)}")


if __name__ == "__main__":
    main()
