"""End-to-end driver (the paper's kind is SERVING): train a small LM on the
synthetic stream until it has real attention structure, then serve batched
long-context requests through the KVSwap engine under a tight memory budget,
comparing generation agreement and modeled throughput against Full-KV.

    PYTHONPATH=src python examples/serve_batched.py [--steps 200] [--batch 4]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineConfig, KVSwapEngine
from repro.data import SyntheticLMStream
from repro.models.transformer import (ModelConfig, TransformerAdapter, forward,
                                      init_params)
from repro.serving import decode as D
from repro.training.optim import AdamWConfig
from repro.training.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--gen-len", type=int, default=48)
    ap.add_argument("--disk", choices=("nvme", "ufs", "emmc"), default="nvme")
    ap.add_argument("--sync-io", action="store_true",
                    help="disable the async prefetch pipeline (bit-identical)")
    args = ap.parse_args()

    cfg = ModelConfig(name="served", arch_type="dense", n_layers=4, d_model=128,
                      n_heads=8, n_kv_heads=4, head_dim=16, d_ff=256,
                      vocab_size=257)
    params = init_params(jax.random.PRNGKey(0), cfg)

    print(f"== training {args.steps} steps on the synthetic stream ==")
    stream = SyntheticLMStream(cfg.vocab_size, seed=7)
    state, _ = train_loop(params, forward, cfg, stream, steps=args.steps,
                          batch=8, seq_len=64, opt_cfg=AdamWConfig(lr=3e-3),
                          log_every=max(args.steps // 5, 1))
    params = state.params

    print("\n== batched serving through KVSwap ==")
    rng = np.random.default_rng(1)
    prompts = stream.batch(10_000, args.batch, args.prompt_len)["tokens"]

    # calibration K from the model itself (paper App. A.1)
    cache = D.init_cache(cfg, args.batch, args.prompt_len + 8)
    _, cache = D.prefill(params, cfg, jnp.asarray(prompts), cache)
    calib = np.asarray(cache["layers"][0]["k"]).reshape(-1, cfg.n_kv_heads, cfg.head_dim)

    adapter = TransformerAdapter(cfg)
    # budget ≈ 2/3 of the context in groups of 4 (tight enough to exercise
    # selection, generous enough that greedy decoding tracks Full-KV)
    n_sel = max(8, (args.prompt_len + args.gen_len) // 6)
    ecfg = EngineConfig(group_size=4, n_select=n_sel, rank=16,
                        reuse_capacity=2 * n_sel,
                        max_seq=args.prompt_len + args.gen_len + 8,
                        disk=args.disk, async_io=not args.sync_io)
    with KVSwapEngine(adapter, params, ecfg, batch=args.batch, calib_k=calib) as eng:
        got = eng.generate(prompts, args.gen_len)
        tput = eng.simulated_throughput()
        reuse = eng.reuse_ratio()
        mem = eng.metadata_bytes()
        on_disk = eng.store.total_bytes_on_disk()
        overlap = eng.overlap_report()

    # Full-KV reference
    toks = jnp.asarray(prompts)
    ref = []
    for _ in range(args.gen_len):
        logits, _ = forward(params, cfg, toks)
        nxt = jnp.argmax(logits[:, -1], -1)
        ref.append(np.asarray(nxt))
        toks = jnp.concatenate([toks, nxt[:, None]], 1)
    ref_arr = np.stack(ref, 1)
    agree = (got == ref_arr).mean()
    # greedy decoding compounds: a single divergence changes the entire
    # suffix — also report how long generations track Full-KV exactly
    prefix = np.argmax(np.concatenate(
        [got != ref_arr, np.ones((got.shape[0], 1), bool)], 1), 1)

    print(f"\nagreement with Full-KV : {agree:.1%}")
    print(f"exact-prefix length    : {prefix.mean():.1f} / {args.gen_len} tokens")
    print(f"reuse ratio            : {reuse:.2f}  (paper: 0.75-0.81)")
    print(f"modeled throughput     : {tput:.1f} tok/s on {args.disk}")
    print(f"KVSwap resident memory : {mem['total']} B "
          f"(full cache on disk: {on_disk} B)")
    print(f"pipeline (modeled)     : io={overlap['io_seconds']*1e3:.3f} ms  "
          f"compute={overlap['compute_seconds']*1e3:.3f} ms  "
          f"pipelined={overlap['pipelined_seconds']*1e3:.3f} ms/step")
    print(f"pipeline (measured)    : io_wait={overlap['io_wait_seconds']*1e3:.2f} ms "
          f"of {overlap['wall_seconds']*1e3:.2f} ms/step "
          f"({'async' if ecfg.async_io else 'sync'} mode)")


if __name__ == "__main__":
    main()
