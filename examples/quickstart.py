"""Quickstart: KVSwap in ~40 lines (mirrors paper Fig. 4).

    PYTHONPATH=src python examples/quickstart.py

Offline: fit the low-rank adapter + pick runtime parameters with the tuner.
Online: serve generation through the disk-backed KVSwap engine.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.core import tuner
from repro.core.engine import EngineConfig, KVSwapEngine
from repro.core.hardware import ModelDims
from repro.core.lowrank import fit_adapter
from repro.models.transformer import ModelConfig, TransformerAdapter, init_params
from repro.utils import MiB

# -- model (a small llama-style decoder) -------------------------------------
cfg = ModelConfig(name="demo", arch_type="dense", n_layers=4, d_model=128,
                  n_heads=8, n_kv_heads=4, head_dim=16, d_ff=256, vocab_size=256)
params = init_params(jax.random.PRNGKey(0), cfg)
adapter_model = TransformerAdapter(cfg)

# -- offline parameter tuning (paper Fig. 4a) ---------------------------------
dims = ModelDims(d_model=cfg.d_model, n_heads=cfg.n_heads,
                 n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim, d_ff=cfg.d_ff)
tuned = tuner.solve(tuner.TunerInputs(
    dims=dims, n_layers=cfg.n_layers, b_max=2, s_max=256,
    budget_bytes=4 * MiB, disk="nvme"))
print("tuned:", tuned.to_json())

# -- offline adapter fit (SVD over a calibration K cache) ---------------------
rng = np.random.default_rng(0)
calib_k = rng.standard_normal((512, cfg.n_kv_heads, cfg.head_dim)).astype(np.float32)
adapter = fit_adapter(calib_k, rank=tuned.rank)

# -- serve (paper Fig. 4b) -----------------------------------------------------
# async_io=True decodes through the background prefetch pipeline (repro.io):
# layer i+1's group reads overlap layer i's compute.  Tokens are bit-identical
# to async_io=False; only wall-clock changes.
ecfg = EngineConfig(group_size=tuned.group_size, n_select=tuned.n_select,
                    rank=tuned.rank, reuse_capacity=max(tuned.reuse_capacity, 16),
                    max_seq=256, disk="nvme", async_io=True)
prompt = rng.integers(0, cfg.vocab_size, (2, 64)).astype(np.int32)
with KVSwapEngine(adapter_model, params, ecfg, batch=2, adapter=adapter) as eng:
    out = eng.generate(prompt, n_new=32)
    print("generated tokens:\n", out)
    print(f"reuse ratio: {eng.reuse_ratio():.2f}")
    print(f"simulated on-device throughput: {eng.simulated_throughput():.1f} tok/s")
    print("in-memory KVSwap state:", eng.metadata_bytes())
    print("overlap report:", {k: round(v, 6) for k, v in eng.overlap_report().items()})
