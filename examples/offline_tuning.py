"""Offline parameter tuning for a target device (paper Fig. 4a / App. A).

Produces the JSON config the runtime consumes: tuned (G, M, σ, C) per
(batch, context) point, plus a fitted low-rank adapter saved as .npz.

    PYTHONPATH=src python examples/offline_tuning.py --arch llama3-8b \
        --budget-mib 310 --disk nvme --out /tmp/kvswap_tuned
"""

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.configs import registry
from repro.core import tuner
from repro.core.hardware import ModelDims
from repro.core.lowrank import fit_adapter
from repro.utils import MiB


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=registry.list_archs())
    ap.add_argument("--budget-mib", type=int, default=310)
    ap.add_argument("--disk", choices=("nvme", "ufs", "emmc"), default="nvme")
    ap.add_argument("--b-max", type=int, default=8)
    ap.add_argument("--s-max", type=int, default=32768)
    ap.add_argument("--out", default="/tmp/kvswap_tuned")
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    dims = ModelDims(d_model=cfg.d_model, n_heads=cfg.n_heads,
                     n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                     d_ff=cfg.d_ff or 4 * cfg.d_model)
    inp = tuner.TunerInputs(dims=dims, n_layers=cfg.n_layers, b_max=args.b_max,
                            s_max=args.s_max, budget_bytes=args.budget_mib * MiB,
                            disk=args.disk)
    # measured reuse table (App. A.1 lookup table #1)
    table = tuner.build_reuse_table()
    grid = tuner.solve_grid(inp, reuse_table=table, b_step=max(args.b_max // 4, 1),
                            s_step=args.s_max // 4, s_min=args.s_max // 4)
    best = tuner.solve(inp, reuse_table=table)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    with open(out / "tuned.json", "w") as f:
        json.dump({"arch": args.arch, "disk": args.disk,
                   "budget_mib": args.budget_mib,
                   "best": json.loads(best.to_json()), "grid": grid}, f, indent=1)

    # adapter from synthetic calibration keys (hook your own via --calib)
    rng = np.random.default_rng(0)
    calib = rng.standard_normal((4096, cfg.n_kv_heads, cfg.head_dim)).astype(np.float32)
    adapter = fit_adapter(calib, rank=best.rank)
    np.savez(out / "adapter.npz", a=np.asarray(adapter.a),
             n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim)

    print(json.dumps(json.loads(best.to_json()), indent=1))
    print(f"wrote {out}/tuned.json and {out}/adapter.npz "
          f"({len(grid)} grid points)")


if __name__ == "__main__":
    main()
