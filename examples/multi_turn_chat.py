"""Multi-turn chat over the persistent prefix cache (``src/repro/cache/``).

The workload the cache is built for: every turn's prompt is the previous
conversation plus a new user message, so turn *t* re-submits turn *t−1*'s
entire token history.  With a session-aware :class:`BatchServer` the server
publishes each turn's served KV blocks and the next turn restores them from
disk — prefill cost stays proportional to the *new* tokens, not the whole
conversation.

    PYTHONPATH=src python examples/multi_turn_chat.py [--turns 4]

Pass ``--cache-dir DIR`` to persist the cache across runs: the second
invocation starts warm from turn 1.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.cache import PrefixCache, PrefixCacheConfig
from repro.core.engine import EngineConfig
from repro.models.transformer import ModelConfig, TransformerAdapter, init_params
from repro.serving.scheduler import BatchServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--turns", type=int, default=4)
    ap.add_argument("--system-len", type=int, default=48,
                    help="shared system-prompt / document tokens")
    ap.add_argument("--user-len", type=int, default=12,
                    help="new user tokens per turn")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--disk", choices=("nvme", "ufs", "emmc"), default="nvme")
    ap.add_argument("--cache-dir", default=None,
                    help="persist the prefix cache here (survives the process)")
    args = ap.parse_args()

    cfg = ModelConfig(name="chat", arch_type="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=211)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    calib = rng.standard_normal((128, cfg.n_kv_heads, cfg.head_dim))

    max_seq = (args.system_len
               + args.turns * (args.user_len + args.max_new) + 16)
    ecfg = EngineConfig(group_size=4, n_select=max_seq // 4, rank=16,
                        reuse_capacity=max_seq // 4, max_seq=max_seq,
                        disk=args.disk, predict_from="self")

    cache = PrefixCache(PrefixCacheConfig(block_tokens=8, dir=args.cache_dir))
    srv = BatchServer(TransformerAdapter(cfg), params, ecfg, batch=1,
                      calib_k=calib, prefix_cache=cache)

    print(f"== {args.turns}-turn chat on {args.disk} "
          f"(cache: {args.cache_dir or 'process-lifetime'}) ==")
    history = rng.integers(0, cfg.vocab_size, args.system_len)  # system prompt
    print("turn,prompt_tokens,cached_tokens,hit_rate,resident_blocks")
    for turn in range(1, args.turns + 1):
        prompt = np.concatenate(
            [history, rng.integers(0, cfg.vocab_size, args.user_len)])
        rid = srv.submit(prompt, max_new=args.max_new)
        srv.flush()
        reply = srv.result(rid)
        # next turn's prompt starts from the full served conversation
        history = np.concatenate([prompt, reply])
        rep = srv.last_stats["prefill"]
        pc = srv.last_stats["prefix_cache"]
        print(f"{turn},{rep['prompt_tokens']},{rep['cached_tokens']},"
              f"{pc['hit_rate']:.2f},{pc['resident_blocks']}")
    tail_rate = srv.last_stats["prefix_cache"]["hit_rate"]
    print(f"\nfinal-turn hit rate: {tail_rate:.1%} — prefill recomputed only "
          f"the newest user tokens (+ the always-recomputed tail block)")
    cache.close()


if __name__ == "__main__":
    main()
