"""A/B the device-resident decode hot path against the host-gather control.

For each (disk, io mode) the same prompt is decoded twice — once with
``EngineConfig.device_resident=False`` (seed behavior: every layer
re-materializes the context on host and re-uploads it) and once with the
device-resident path (reuse-mirror delta scatters + device rolling buffer +
fused prediction).  Reported per decode step, warmup excluded:

* ``wall_ms``        — measured host wall time (the number that must drop),
* ``io_wait_ms``     — measured time blocked on fetches,
* ``h2d_kb``         — host→device KV payload bytes actually shipped,
* ``pipelined_ms``   — modeled layer-pipelined latency (DiskSpec+ComputeSpec;
                       identical between paths by construction).

Checks (full mode): decoded tokens are bit-identical, measured mean wall per
step is strictly lower device-resident on the default config, and the upload
bytes shrink by at least the measured reuse hit rate — the delta-upload
contract.  Emits machine-readable ``BENCH_decode_hotpath.json``.

Usage::

    PYTHONPATH=src python -m benchmarks.decode_hotpath [--tiny] [--steps N]
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import write_bench_json  # noqa: F401  (src/ bootstrap)

from repro.core.engine import EngineConfig, KVSwapEngine
from repro.models.transformer import ModelConfig, TransformerAdapter, init_params


def build_model(tiny: bool):
    if tiny:
        cfg = ModelConfig(name="hotpath-tiny", arch_type="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                          d_ff=128, vocab_size=128)
    else:
        cfg = ModelConfig(name="hotpath", arch_type="dense", n_layers=4,
                          d_model=128, n_heads=8, n_kv_heads=4, head_dim=16,
                          d_ff=256, vocab_size=512)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, TransformerAdapter(cfg), params


def run_one(adapter, params, prompt, calib, *, disk: str, async_io: bool,
            device_resident: bool, steps: int, ecfg_kw: dict) -> tuple[np.ndarray, dict]:
    ecfg = EngineConfig(disk=disk, async_io=async_io,
                        device_resident=device_resident, **ecfg_kw)
    with KVSwapEngine(adapter, params, ecfg, batch=prompt.shape[0],
                      calib_k=calib) as eng:
        toks = eng.generate(prompt, steps)
        # warmup: the first G steps compile one context-shape variant per
        # rolling fill; measure steady state only
        skip = min(ecfg.group_size + 4, max(1, steps - 2))
        rep = eng.overlap_report(skip=skip)
        walls = [s.wall_seconds for s in eng.step_log[skip:]]
        row = {
            "disk": disk,
            "async_io": async_io,
            "device_resident": device_resident,
            "wall_ms": rep["wall_seconds"] * 1e3,
            # median is the robust per-step figure: it ignores the once-per-G
            # flush sync and scheduler noise that skew a short run's mean
            "wall_median_ms": float(np.median(walls)) * 1e3,
            "io_wait_ms": rep["io_wait_seconds"] * 1e3,
            "pipelined_ms": rep["pipelined_seconds"] * 1e3,
            "h2d_kb": rep["h2d_bytes"] / 1024,
            "reuse_hit_rate": eng.reuse_ratio(),
            # prefetch quality (1-step lookahead, ROADMAP item 4 baseline)
            "pred_precision": rep["pred_precision"],
            "pred_recall": rep["pred_recall"],
            "stale_group_rate": rep["stale_group_rate"],
        }
    return toks, row


def main(tiny: bool = False, steps: int | None = None) -> dict:
    cfg, adapter, params = build_model(tiny)
    rng = np.random.default_rng(0)
    prompt_len = 96 if tiny else 512
    steps = steps or (10 if tiny else 24)
    batch = 2
    prompt = rng.integers(0, cfg.vocab_size, (batch, prompt_len)).astype(np.int32)
    calib = rng.standard_normal((512, cfg.n_kv_heads, cfg.head_dim)).astype(np.float32)
    ecfg_kw = dict(
        group_size=4,
        n_select=8 if tiny else 32,
        rank=16 if tiny else 32,
        # sized to the paper's Fig. 8 regime (75-81 % step-to-step overlap):
        # C covers the prompt's groups, so steady-state misses are mostly
        # the freshly flushed groups plus selection churn
        reuse_capacity=16 if tiny else 128,
        max_seq=256 if tiny else 1024,
    )
    grid = [("nvme", False)] if tiny else [
        ("nvme", False), ("nvme", True), ("emmc", False), ("emmc", True)]

    rows = []
    print("disk,async_io,device_resident,wall_ms,wall_median_ms,io_wait_ms,"
          "h2d_kb,pipelined_ms,hit_rate")
    for disk, aio in grid:
        toks = {}
        for dr in (False, True):
            toks[dr], row = run_one(adapter, params, prompt, calib, disk=disk,
                                    async_io=aio, device_resident=dr,
                                    steps=steps, ecfg_kw=ecfg_kw)
            rows.append(row)
            print(f"{disk},{aio},{dr},{row['wall_ms']:.2f},"
                  f"{row['wall_median_ms']:.2f},{row['io_wait_ms']:.3f},"
                  f"{row['h2d_kb']:.1f},{row['pipelined_ms']:.3f},"
                  f"{row['reuse_hit_rate']:.3f}")
        assert np.array_equal(toks[False], toks[True]), \
            f"device-resident tokens diverged from host-gather ({disk}, async={aio})"

    # the acceptance gate, on the default config (first grid entry):
    # measured wall strictly lower, uploads reduced >= the reuse hit rate.
    # Wall-clock is a single-sample measurement — one scheduler hiccup can
    # flip a ~1.4x median win, so the gate re-measures the default pair
    # (fresh engines, warm jit caches) before declaring a regression.
    host, dev = rows[0], rows[1]
    for retry in range(2):
        if tiny or dev["wall_median_ms"] < host["wall_median_ms"]:
            break
        print(f"retrying noisy wall measurement ({dev['wall_median_ms']:.2f} "
              f">= {host['wall_median_ms']:.2f} ms)")
        disk, aio = grid[0]
        _, host = run_one(adapter, params, prompt, calib, disk=disk,
                          async_io=aio, device_resident=False, steps=steps,
                          ecfg_kw=ecfg_kw)
        _, dev = run_one(adapter, params, prompt, calib, disk=disk,
                         async_io=aio, device_resident=True, steps=steps,
                         ecfg_kw=ecfg_kw)
        rows[0], rows[1] = host, dev
    speedup = host["wall_median_ms"] / max(dev["wall_median_ms"], 1e-9)
    bytes_reduction = 1.0 - dev["h2d_kb"] / max(host["h2d_kb"], 1e-9)
    summary = {
        "wall_speedup": speedup,
        "h2d_bytes_reduction": bytes_reduction,
        "reuse_hit_rate": dev["reuse_hit_rate"],
    }
    print(f"speedup={speedup:.2f}x (median step wall)  "
          f"h2d_reduction={bytes_reduction:.1%}  "
          f"hit_rate={dev['reuse_hit_rate']:.1%}")

    out = {"model": cfg.name, "prompt_len": prompt_len, "steps": steps,
           "batch": batch, "engine": ecfg_kw, "results": rows, "summary": summary}
    write_bench_json("decode_hotpath", out, tiny=tiny)

    if not tiny:   # timing asserts are too noisy for the CI smoke
        assert dev["wall_median_ms"] < host["wall_median_ms"], \
            (f"device-resident not faster: {dev['wall_median_ms']:.2f} >= "
             f"{host['wall_median_ms']:.2f} ms")
        assert bytes_reduction >= dev["reuse_hit_rate"] - 0.05, \
            f"uploads shrank {bytes_reduction:.1%} < hit rate {dev['reuse_hit_rate']:.1%}"
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: one config, no timing asserts")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    main(tiny=args.tiny, steps=args.steps)
