"""Multi-replica affinity routing benchmark: KV locality as a fleet asset.

A mixed-tenant chat trace (interleaved per-tenant conversations with
growing shared prefixes, Poisson-ish start offsets and think-time gaps —
:func:`repro.serving.trace.mixed_tenant_trace`) is routed across N=3
independent replicas (each its own ServeSession + int8 PrefixCache) by
two policies:

* ``round_robin`` — the locality-blind baseline: a tenant's turns spray
  across replicas, so each replica holds only a fragment of the
  conversation's block chain and most prefills run cold;
* ``prefix_affinity`` — scores replicas by longest cached prefix (the
  side-effect-free ``PrefixCache.peek`` over the same content-addressed
  chain) blended with load, keeping every tenant's turns on the replica
  that already holds their KV.

Sweep: disk ∈ {nvme, ufs} × policy, near fleet saturation (arrival
pacing calibrated from a solo ufs service probe), modeled Orin-Nano
compute, int8 disk tier + int8 prefix slabs — the slo_trace platform.

Asserted invariants (the run fails otherwise):

* every disk: affinity **beats** round-robin on the fleet warm-prefill
  hit rate (cached prompt tokens / prompt tokens) — the locality claim;
* every disk: affinity **beats** round-robin on goodput-under-SLO —
  locality translates into latency headroom under load, not just fewer
  reads;
* routed generation is **bit-identical** to solo unrouted sessions: for
  each replica's routed arrival pattern, a fresh solo session given
  exactly those submissions reproduces every token stream;
* both policies complete every trace request (no shedding is configured,
  so a loss would be a scheduler bug).

    PYTHONPATH=src python -m benchmarks.router_affinity [--tiny]
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from benchmarks.common import write_bench_json  # noqa: F401  (src/ bootstrap)

EPS = 1e-9
N_REPLICAS = 3


def build_model():
    import jax

    from repro.models.transformer import ModelConfig, init_params

    # the slo_trace platform: small enough for CPU prefill in seconds, big
    # enough that modeled Orin-Nano prefill compute dominates a same-length
    # int8 restore read — the regime where prefix locality pays
    cfg = ModelConfig(name="router-bench", arch_type="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=1, head_dim=16,
                      d_ff=1024, vocab_size=512)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def base_engine_cfg(max_seq: int):
    from repro.core.engine import EngineConfig

    return EngineConfig(group_size=4, n_select=20, rank=16,
                        reuse_capacity=12, max_seq=max_seq, kv_bits=8,
                        predict_from="self", compute="jetson-orin-nano")


def make_session(cfg, params, calib, ecfg, *, slots, prefix_cache=None):
    from repro.models.transformer import TransformerAdapter
    from repro.serving.api import ServeSession

    return ServeSession(TransformerAdapter(cfg), params, ecfg, slots=slots,
                        calib_k=calib, prefix_cache=prefix_cache)


def make_fleet(cfg, params, calib, ecfg, policy, *, slots):
    from repro.cache import PrefixCache, PrefixCacheConfig
    from repro.router import FrontEnd, ReplicaPool

    pool = ReplicaPool()
    for i in range(N_REPLICAS):
        pc = PrefixCache(PrefixCacheConfig(block_tokens=8, kv_bits=8))
        pool.add(f"r{i}",
                 make_session(cfg, params, calib, ecfg, slots=slots,
                              prefix_cache=pc))
    return FrontEnd(pool, policy)


def probe_service(cfg, params, calib, ecfg, *, prompt_tokens, max_new,
                  rng) -> dict:
    """Solo-request cold service profile on an idle ufs session — the
    time scale the SLO thresholds and arrival pacing derive from."""
    dcfg = dataclasses.replace(ecfg, disk="ufs")
    with make_session(cfg, params, calib, dcfg, slots=1) as sess:
        sess.submit(rng.integers(0, cfg.vocab_size, prompt_tokens), max_new)
        sess.drain()
        rec = sess.per_request()[0]
        return {"ttft_s": rec["ttft_seconds"], "tpot_s": rec["tpot_seconds"],
                "service_s": rec["e2e_seconds"]}


def run_fleet(cfg, params, calib, ecfg, policy, trace, *, slots):
    """One cell: fresh fleet, route the trace as-it-arrives, return the
    aggregate plus the per-request routing table (for bit-identity)."""
    front = make_fleet(cfg, params, calib, ecfg, policy, slots=slots)
    try:
        out = front.replay(trace)
        routes = [front.route_of(i) for i in range(trace.n_requests)]
        tokens = {i: np.asarray(front.result(i)).tolist()
                  for i in range(trace.n_requests)}
        return out, routes, tokens
    finally:
        front.close()


def verify_bit_identity(cfg, params, calib, ecfg, trace, routes, tokens,
                        *, slots) -> list[str]:
    """Replay each replica's routed arrival pattern through a fresh solo
    session; every token stream must match the routed run exactly."""
    from repro.cache import PrefixCache, PrefixCacheConfig

    failures = []
    by_replica: dict[str, list[int]] = {}
    for i, name in enumerate(routes):
        by_replica.setdefault(name, []).append(i)
    for name, rids in by_replica.items():
        with PrefixCache(PrefixCacheConfig(block_tokens=8, kv_bits=8)) as pc:
            with make_session(cfg, params, calib, ecfg, slots=slots,
                              prefix_cache=pc) as solo:
                local = {}
                for i in rids:
                    r = trace.requests[i]
                    local[i] = solo.submit(
                        r.materialize(trace.vocab_size), r.max_new,
                        arrival=r.arrival, slo_class=r.slo_class,
                        tenant=r.tenant)
                solo.drain()
                for i in rids:
                    got = np.asarray(solo.completed[local[i]].output).tolist()
                    if got != tokens[i]:
                        failures.append(
                            f"request {i} on {name}: routed tokens diverge "
                            f"from solo session")
    return failures


def main(tiny: bool = False) -> None:
    from repro.router import PrefixAffinityRouter, RoundRobin
    from repro.serving.metrics import SLOClass
    from repro.serving.trace import mixed_tenant_trace

    cfg, params = build_model()
    rng = np.random.default_rng(0)
    calib = rng.standard_normal((256, cfg.n_kv_heads, cfg.head_dim)
                                ).astype(np.float32)
    slots = 2
    tenants, turns = (4, 3) if tiny else (6, 4)
    sys_tokens, user_tokens, max_new = 96, 16, 10
    max_seq = 320
    ecfg = base_engine_cfg(max_seq)

    # -- calibrate: SLO thresholds + arrival pacing off a ufs solo probe --
    final_prompt = sys_tokens + turns * user_tokens
    probe = probe_service(cfg, params, calib, ecfg, rng=rng,
                          prompt_tokens=final_prompt, max_new=max_new)
    slo_classes = {"interactive": SLOClass(
        "interactive", ttft_s=1.5 * probe["ttft_s"],
        tpot_s=2.0 * probe["tpot_s"])}
    # pace the fleet near saturation: `tenants` arrivals per turn gap vs
    # N_REPLICAS * slots cold service lanes, ~90 % utilization — busy
    # enough that locality decides who meets the SLO, not so overloaded
    # that every policy drowns
    turn_gap = tenants * probe["service_s"] / (N_REPLICAS * slots) / 0.9
    trace = mixed_tenant_trace(
        17, tenants=tenants, turns=turns, sys_tokens=sys_tokens,
        user_tokens=user_tokens, max_new=max_new, turn_gap_s=turn_gap,
        start_spread_s=turn_gap / tenants, slo_classes=slo_classes,
        vocab_size=cfg.vocab_size)

    disks = ("nvme",) if tiny else ("nvme", "ufs")
    policies = {"round_robin": RoundRobin, "prefix_affinity": PrefixAffinityRouter}
    out = {
        "model": dataclasses.asdict(cfg),
        "engine": {"base": dataclasses.asdict(ecfg), "slots": slots,
                   "n_replicas": N_REPLICAS},
        "slo_classes": {n: c.to_dict() for n, c in slo_classes.items()},
        "probe_ufs": probe,
        "trace": {"workload": trace.workload, "seed": trace.seed,
                  "tenants": tenants, "turns": turns,
                  "n_requests": trace.n_requests, "turn_gap_s": turn_gap},
        "disks": {},
    }
    failures: list[str] = []
    print("disk,policy,prefix_hit_rate,ttft_p95_ms,slo_attainment,"
          "goodput_under_slo_tok_s,routed_spread")
    for disk in disks:
        dcfg = dataclasses.replace(ecfg, disk=disk)
        cells = out["disks"][disk] = {}
        for pname, pcls in policies.items():
            m, routes, tokens = run_fleet(cfg, params, calib, dcfg, pcls(),
                                          trace, slots=slots)
            fleet = m.pop("fleet")
            del m["per_request"]
            spread = {n: p["routed"] for n, p in fleet["replicas"].items()}
            cells[pname] = {
                **m,
                "prefix_hit_rate": fleet["prefix_hit_rate"],
                "cached_prompt_tokens": fleet["cached_prompt_tokens"],
                "completed_requests": fleet["completed_requests"],
                "routed_spread": spread,
            }
            print(f"{disk},{pname},{fleet['prefix_hit_rate']:.3f},"
                  f"{m['ttft']['p95'] * 1e3:.3f},{m['slo_attainment']:.2f},"
                  f"{m['goodput_under_slo_tokens_per_s']:.1f},{spread}")
            if fleet["completed_requests"] != trace.n_requests:
                failures.append(
                    f"{disk}/{pname}: completed "
                    f"{fleet['completed_requests']} of {trace.n_requests}")
            if pname == "prefix_affinity":
                failures += verify_bit_identity(
                    cfg, params, calib, dcfg, trace, routes, tokens,
                    slots=slots)
        rr, aff = cells["round_robin"], cells["prefix_affinity"]
        if aff["prefix_hit_rate"] <= rr["prefix_hit_rate"] + EPS:
            failures.append(
                f"{disk}: affinity warm-prefill hit rate "
                f"{aff['prefix_hit_rate']:.3f} does not beat round-robin "
                f"{rr['prefix_hit_rate']:.3f}")
        if aff["goodput_under_slo_tokens_per_s"] <= \
                rr["goodput_under_slo_tokens_per_s"] + EPS:
            failures.append(
                f"{disk}: affinity goodput-under-SLO "
                f"{aff['goodput_under_slo_tokens_per_s']:.2f} does not beat "
                f"round-robin "
                f"{rr['goodput_under_slo_tokens_per_s']:.2f}")

    out["invariants_ok"] = not failures
    write_bench_json("router_affinity", out, tiny=tiny)
    if failures:
        raise SystemExit("router affinity invariants failed:\n  "
                         + "\n  ".join(failures))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke mode: nvme only, smaller trace")
    main(tiny=ap.parse_args().tiny)
