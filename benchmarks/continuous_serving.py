"""Continuous batching vs the static batcher on a Poisson arrival trace.

Goodput A/B for the serving API redesign: the same mixed-length request
trace (Poisson arrivals, mixed prompt lengths, mixed ``max_new``) is served
two ways through the *same* persistent-engine machinery:

* **continuous** — :class:`repro.serving.api.ServeSession` as designed:
  per-slot admission the moment a slot frees up, per-request retirement the
  moment a request finishes (a retired slot charges zero further IO);
* **static** — the legacy ``BatchServer.flush()`` discipline, emulated on
  the session so both arms share one engine implementation: requests are
  ganged into batches of ``slots`` in arrival order, a batch starts only
  when its **last** member has arrived and the previous batch finished,
  short batches are padded with clone rows that burn real disk reads, and
  every row decodes to the batch-max ``max_new`` before truncating.

Goodput counts **completed-request tokens per modeled second** — each
request contributes exactly its own ``max_new``; the clock is the modeled
DiskSpec + ComputeSpec time (admission prefill seconds + pipelined decode
seconds).  The continuous arm must win on both nvme and emmc or this
benchmark fails the run.

    PYTHONPATH=src python -m benchmarks.continuous_serving [--tiny] \
        [--trace obs_trace.json]

``--trace PATH`` attaches an :class:`repro.obs.Observability` handle to the
first continuous run and exports its dual-clock Perfetto trace to PATH —
the artifact CI uploads from the tiny smoke.
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from benchmarks.common import write_bench_json  # noqa: F401  (src/ bootstrap)


def build_model():
    import jax

    from repro.models.transformer import ModelConfig, init_params

    cfg = ModelConfig(name="serve-bench", arch_type="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128, vocab_size=211)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def build_trace(rng, *, n_requests, prompt_lo, prompt_hi, gen_lo, gen_hi,
                mean_interarrival):
    """Mixed-length requests with Poisson (exponential-gap) arrivals."""
    reqs = []
    t = 0.0
    for _ in range(n_requests):
        t += float(rng.exponential(mean_interarrival))
        reqs.append({
            "prompt_len": int(rng.integers(prompt_lo, prompt_hi + 1)),
            "max_new": int(rng.integers(gen_lo, gen_hi + 1)),
            "arrival": t,
        })
    return reqs


def _session(cfg, params, ecfg, slots, calib, obs=None):
    from repro.models.transformer import TransformerAdapter
    from repro.serving.api import ServeSession

    return ServeSession(TransformerAdapter(cfg), params, ecfg, slots=slots,
                        calib_k=calib, obs=obs)


def run_continuous(cfg, params, ecfg, slots, calib, trace, prompts,
                   obs=None) -> dict:
    with _session(cfg, params, ecfg, slots, calib, obs=obs) as sess:
        for r, p in zip(trace, prompts):
            sess.submit(p, r["max_new"], arrival=r["arrival"])
        done = sess.drain()
        tokens = sum(len(q.output) for q in done.values())
        snap = sess.engine.accountant.snapshot()
        return {"tokens": tokens, "makespan": sess.now,
                "goodput": tokens / sess.now,
                "read_bytes": snap["read_bytes"],
                "decode_steps": len(sess.engine.step_log)}


def run_static(cfg, params, ecfg, slots, calib, trace, prompts) -> dict:
    """Legacy flush discipline on the same engine machinery (see module
    docstring): gang-scheduled batches, clone padding, decode-to-batch-max."""
    with _session(cfg, params, ecfg, slots, calib) as sess:
        useful = 0
        for i in range(0, len(trace), slots):
            batch = trace[i:i + slots]
            bprompts = list(prompts[i:i + slots])
            # the flush can only start once the whole batch has arrived
            sess.now = max(sess.now, max(r["arrival"] for r in batch))
            batch_max = max(r["max_new"] for r in batch)
            while len(bprompts) < slots:        # clone padding burns real IO
                bprompts.append(bprompts[0])
            for p in bprompts:
                sess.submit(p, batch_max)       # everyone rides to batch max
            sess.drain()
            useful += sum(r["max_new"] for r in batch)
        snap = sess.engine.accountant.snapshot()
        return {"tokens": useful, "makespan": sess.now,
                "goodput": useful / sess.now,
                "read_bytes": snap["read_bytes"],
                "decode_steps": len(sess.engine.step_log)}


def main(tiny: bool = False, trace_path: str | None = None) -> None:
    from repro.core.engine import EngineConfig
    from repro.obs import Observability

    cfg, params = build_model()
    rng = np.random.default_rng(0)
    calib = rng.standard_normal((128, cfg.n_kv_heads, cfg.head_dim))
    slots = 2 if tiny else 4
    n_requests = 6 if tiny else 24
    prompt_lo, prompt_hi = (12, 24) if tiny else (16, 48)
    gen_lo, gen_hi = (2, 6) if tiny else (4, 16)
    max_seq = prompt_hi + gen_hi + 8
    ecfg = EngineConfig(group_size=4, n_select=max_seq // 8, rank=16,
                        reuse_capacity=max_seq // 8, max_seq=max_seq,
                        predict_from="self")

    # calibrate the arrival rate to the modeled service rate: one solo
    # request measures prefill + per-token seconds, then the trace targets
    # ~80 % utilization of the slot pool
    with _session(cfg, params, ecfg, slots, calib) as probe:
        probe.submit(rng.integers(0, cfg.vocab_size, prompt_hi), gen_hi)
        probe.drain()
        service = probe.now / gen_hi
    mean_interarrival = 0.8 * service * (gen_lo + gen_hi) / 2 / slots

    trace = build_trace(rng, n_requests=n_requests, prompt_lo=prompt_lo,
                        prompt_hi=prompt_hi, gen_lo=gen_lo, gen_hi=gen_hi,
                        mean_interarrival=mean_interarrival)
    prompts = [rng.integers(0, cfg.vocab_size, r["prompt_len"]) for r in trace]

    out = {"slots": slots, "n_requests": n_requests,
           "mean_interarrival_s": mean_interarrival, "disks": {}}
    print("disk,arm,goodput_tok_s,makespan_s,read_MB,decode_steps")
    ok = True
    obs = Observability() if trace_path else None
    for disk in ("nvme", "emmc"):
        dcfg = dataclasses.replace(ecfg, disk=disk)
        cont = run_continuous(cfg, params, dcfg, slots, calib, trace, prompts,
                              obs=obs)
        if obs is not None:       # trace the first continuous run only
            obs.export_trace(trace_path)
            print(f"wrote {trace_path}")
            obs = None
        stat = run_static(cfg, params, dcfg, slots, calib, trace, prompts)
        speedup = cont["goodput"] / stat["goodput"]
        out["disks"][disk] = {"continuous": cont, "static": stat,
                              "goodput_speedup": speedup}
        for arm, r in (("continuous", cont), ("static", stat)):
            print(f"{disk},{arm},{r['goodput']:.1f},{r['makespan']:.4f},"
                  f"{r['read_bytes'] / 1e6:.2f},{r['decode_steps']}")
        print(f"{disk},speedup,{speedup:.2f}x,,,")
        ok &= speedup > 1.0

    write_bench_json("continuous_serving", out, tiny=tiny)
    if not ok:
        raise SystemExit("continuous batching did not beat the static "
                         "batcher on every disk")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke mode: small trace")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export a Perfetto trace of the first continuous "
                         "run to PATH")
    args = ap.parse_args()
    main(tiny=args.tiny, trace_path=args.trace)
