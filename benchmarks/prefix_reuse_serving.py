"""Cross-request prefix reuse: cold vs warm prefill (``src/repro/cache/``).

Two sections:

* ``run_modeled`` — analytic latency for a realistic on-device deployment
  (llama3-8b dims, Jetson Orin compute, int8 KV on disk per §7): cold
  prefill = full-attention compute + KV spill writes; warm prefill =
  sequential restore reads of the cached prefix + the same writes + chunked
  compute of the uncached suffix.  Reported for both NVMe and eMMC specs;
  the headline claim is **warm < 0.5× cold on both**.

* ``run_session`` — a real session through :class:`BatchServer` with a
  persistent :class:`PrefixCache`: flush 1 publishes the system prompt cold,
  flush 2 restores it warm.  Reports the measured cache hit rate and saved
  prefill tokens from ``last_stats`` (tiny model — the *modeled* speedup at
  this scale is compute-poor, which is exactly why the analytic section
  uses deployment dims).

Usage::

    PYTHONPATH=src python -m benchmarks.prefix_reuse_serving [--tiny]
"""

from __future__ import annotations

import argparse

from benchmarks.common import LLAMA3_8B, N_LAYERS
from repro.core.hardware import ORIN, ModelDims, prefill_layer_time
from repro.core.offload import DISKS, DiskSpec


def modeled_prefill_seconds(
    disk: DiskSpec,
    dims: ModelDims,
    n_layers: int,
    *,
    s: int,
    s_cached: int,
    kv_itemsize: int = 1,
    batch: int = 1,
) -> dict:
    """Modeled warm-prefill latency with ``s_cached`` of ``s`` prompt tokens
    restored from the prefix cache (``s_cached=0`` = cold).

    Mirrors the engine's accounting: restore is one sequential run per layer
    of the unique chain (batched rows share the prompt, so it is read once);
    the spill writes cover the full prompt either way; compute covers only
    the uncached suffix, chunked over the restored context.
    """
    ent_layer = 2 * dims.n_kv_heads * dims.head_dim * kv_itemsize  # B/token/layer
    restore = disk.read_time(n_layers * s_cached * ent_layer, n_layers) if s_cached else 0.0
    writes = disk.write_time(batch * n_layers * s * ent_layer, batch * n_layers)
    compute = n_layers * prefill_layer_time(ORIN, dims, n_new=s - s_cached,
                                            n_ctx0=s_cached, batch=batch)
    return {"restore_s": restore, "write_s": writes, "compute_s": compute,
            "total_s": restore + writes + compute}


def run_modeled(*, s: int = 4096, cached_frac: float = 0.875,
                kv_itemsize: int = 1, batch: int = 1) -> dict:
    """Cold vs warm modeled prefill on both disk specs.  Returns the ratios."""
    dims, n_layers = LLAMA3_8B, N_LAYERS["llama3-8b"]
    s_cached = int(s * cached_frac)
    fmt = "int8" if kv_itemsize == 1 else f"{8 * kv_itemsize}-bit"
    print(f"# llama3-8b dims, S={s}, cached={s_cached} ({cached_frac:.1%}), "
          f"disk KV {fmt}, batch={batch}")
    print("disk,cold_ms,warm_ms,warm/cold,restore_ms,write_ms,suffix_compute_ms")
    ratios = {}
    for name, disk in DISKS.items():
        cold = modeled_prefill_seconds(disk, dims, n_layers, s=s, s_cached=0,
                                       kv_itemsize=kv_itemsize, batch=batch)
        warm = modeled_prefill_seconds(disk, dims, n_layers, s=s, s_cached=s_cached,
                                       kv_itemsize=kv_itemsize, batch=batch)
        ratio = warm["total_s"] / cold["total_s"]
        ratios[name] = ratio
        print(f"{name},{cold['total_s'] * 1e3:.1f},{warm['total_s'] * 1e3:.1f},"
              f"{ratio:.3f},{warm['restore_s'] * 1e3:.1f},"
              f"{warm['write_s'] * 1e3:.1f},{warm['compute_s'] * 1e3:.1f}")
    return ratios


def run_session(*, sys_len: int = 48, user_len: int = 8, max_new: int = 4,
                batch: int = 2) -> dict:
    """Drive a real BatchServer session: cold flush, then a warm one."""
    import jax
    import numpy as np

    from repro.cache import PrefixCache, PrefixCacheConfig
    from repro.core.engine import EngineConfig
    from repro.models.transformer import (ModelConfig, TransformerAdapter,
                                          init_params)
    from repro.serving.scheduler import BatchServer

    cfg = ModelConfig(name="bench", arch_type="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=211)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    calib = rng.standard_normal((128, cfg.n_kv_heads, cfg.head_dim))
    max_seq = sys_len + user_len + max_new + 16
    ecfg = EngineConfig(group_size=4, n_select=max_seq // 4, rank=16,
                        reuse_capacity=max_seq // 4, max_seq=max_seq,
                        predict_from="self")
    stats = {}
    with PrefixCache(PrefixCacheConfig(block_tokens=8)) as cache:
        srv = BatchServer(TransformerAdapter(cfg), params, ecfg, batch=batch,
                          calib_k=calib, prefix_cache=cache)
        sys_prompt = rng.integers(0, cfg.vocab_size, sys_len)
        print("flush,hit_rate,saved_prefill_tokens,resident_blocks")
        for flush in ("cold", "warm"):
            for _ in range(batch):
                prompt = np.concatenate(
                    [sys_prompt, rng.integers(0, cfg.vocab_size, user_len)])
                srv.submit(prompt, max_new=max_new)
            pc = srv.last_stats["prefix_cache"]
            stats[flush] = pc
            print(f"{flush},{pc['hit_rate']:.3f},{pc['saved_prefill_tokens']},"
                  f"{pc['resident_blocks']}")
    return stats


def main(tiny: bool = False) -> None:
    print("== modeled cold vs warm prefill (deployment dims) ==")
    ratios = run_modeled(s=512 if tiny else 4096)
    print("== live BatchServer session (tiny model, real KV restore) ==")
    session = run_session(sys_len=24 if tiny else 48,
                          user_len=8, max_new=3 if tiny else 4)
    ok_model = all(r < 0.5 for r in ratios.values())
    ok_hits = session["warm"]["hit_rate"] > 0.0
    print(f"warm<0.5x cold on all disks: {ok_model}; warm flush hit: {ok_hits}")
    if not (ok_model and ok_hits):
        raise SystemExit("prefix reuse benchmark regressed")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke mode: small prompt sizes")
    main(tiny=ap.parse_args().tiny)
