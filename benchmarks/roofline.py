"""Roofline analysis (§Roofline of the reproduction brief).

Reads the dry-run records (experiments/dryrun_single.json — produced by
``python -m repro.launch.dryrun --all --out ...``) and derives, per
(arch × shape):

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip)

Conventions: ``cost_analysis`` and the parsed HLO are the *per-device* SPMD
program, so terms are already per chip; constants are TPU v5e
(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).

MODEL_FLOPS uses the brief's bookkeeping: 6·N·D for training tokens
(fwd+bwd), and the forward-only 2·N·D (N_active for MoE) for
prefill/decode, labeled accordingly.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import Timer, emit, write_bench_json

PEAK = 197e12
HBM = 819e9
LINK = 50e9
CHIPS = {"16x16": 256, "2x16x16": 512}

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "experiments", "dryrun_single.json")


def model_flops(arch: str, shape: str) -> tuple[float, str]:
    """Useful model FLOPs for the whole step (global, all chips)."""
    from repro.configs import registry
    from repro.configs.shapes import SHAPES
    cfg = registry.get(arch)
    sh = SHAPES[shape]
    if registry.is_whisper(cfg):
        # decoder+encoder params, approximate with total
        n_params = (cfg.vocab_size * cfg.d_model
                    + 2 * cfg.n_layers * (4 * cfg.d_model * cfg.d_model
                                          + 2 * cfg.d_model * cfg.d_ff))
        n_active = n_params
    else:
        n_params = cfg.param_count()
        n_active = cfg.active_param_count()
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        # MoE trains only the routed top-k experts per token
        return 6.0 * n_active * tokens, "6·N_active·D (train)"
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n_active * tokens, "2·N_active·D (fwd)"
    tokens = sh.global_batch  # one token per sequence
    return 2.0 * n_active * tokens, "2·N_active·D (fwd)"


def analyze(records: list[dict]) -> list[dict]:
    rows = []
    for r in records:
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"], "ok": False,
                         "error": r.get("error", "")[:120]})
            continue
        chips = CHIPS.get(r["mesh"], 256)
        t_c = r["flops"] / PEAK
        t_m = r["bytes_accessed"] / HBM
        t_x = r["collective_bytes"] / LINK
        dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
                  key=lambda kv: kv[1])[0]
        mf, mf_kind = model_flops(r["arch"], r["shape"])
        ratio = mf / (r["flops"] * chips) if r["flops"] else 0.0
        fix = {
            "compute": "cut redundant compute (remat policy, fuse GQA repeat, "
                       "avoid recomputed projections)",
            "memory": "shrink the streamed working set (KVSwap selection, "
                      "bf16 cache, fuse elementwise chains into the matmuls)",
            "collective": "reshard to keep the dominant tensor local "
                          "(expert-parallel all-to-all sizing, seq-local "
                          "flash-decode combine, overlap collectives)",
        }[dom]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "kvswap": r.get("kvswap", False), "ok": True,
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom, "model_flops": mf, "model_flops_kind": mf_kind,
            "useful_ratio": ratio, "next_move": fix,
        })
    return rows


def print_table(rows: list[dict]) -> None:
    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':8s} {'compute_s':>11s} "
           f"{'memory_s':>11s} {'collect_s':>11s} {'dominant':>10s} {'useful':>7s}")
    print(hdr)
    for r in rows:
        if not r["ok"]:
            print(f"{r['arch']:26s} {r['shape']:12s} FAILED {r['error']}")
            continue
        print(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['compute_s']:11.3e} {r['memory_s']:11.3e} "
              f"{r['collective_s']:11.3e} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.3f}")


def main(path: str = DEFAULT_PATH) -> str:
    if not os.path.exists(path):
        emit("roofline", 0, "SKIPPED (run repro.launch.dryrun --all --out first)")
        return "skipped"
    with Timer() as t:
        with open(path) as f:
            records = json.load(f)
        rows = analyze(records)
        print_table(rows)
        write_bench_json("roofline", rows,
                         path=path.replace(".json", "_roofline.json"),
                         indent=1)
    ok = [r for r in rows if r["ok"]]
    doms = {d: sum(1 for r in ok if r["dominant"] == d)
            for d in ("compute", "memory", "collective")}
    emit("roofline", t.us,
         f"n={len(ok)}/{len(rows)} dominants={doms}")
    return "ok"


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else DEFAULT_PATH)
