"""Trace-driven SLO serving benchmark: TTFT/TPOT tails + attainment.

The paper's real serving question is not mean goodput but whether KVSwap
holds **latency SLOs** for interactive users under bursty long-context load
on nvme/ufs/emmc-class storage.  This harness replays the three
seed-deterministic workload traces from :mod:`repro.serving.trace` —
multi-turn chat (prefix-reuse heavy), long-doc summarization (prefill
heavy), Poisson bursts (queueing heavy) — through the persistent
:class:`~repro.serving.api.ServeSession` on the modeled clock, sweeping

    disk ∈ {nvme, ufs, emmc}  ×  warm tier {on, off}  ×  prefix cache {on, off}

and reports TTFT/TPOT p50/p95/p99, per-class SLO attainment and
goodput-under-SLO per cell (:mod:`repro.serving.metrics`).  Every
feature configuration replays the *same* trace file against the *same*
SLO contract, so cells differ only in the serving stack.

Platform: the modeled compute is the Jetson **Orin Nano** class
(``hardware.ORIN_NANO``) — the entry on-device tier where UFS/eMMC
storage is actually found — with the int8 disk tier (``kv_bits=8``) and
an int8 prefix-cache slab, so restore reads and prefill compute sit at
realistic relative scales for the small benchmark model.

Asserted invariants (the run fails otherwise):

* chat, every disk: **warm+prefix is never worse than the baseline on
  TTFT p95** (the tentpole claim: restoring a published conversation
  prefix beats recomputing it, even at eMMC latencies);
* chat baseline TTFT p50 is monotone in disk speed (nvme ≤ ufs ≤ emmc);
* warm+prefix never reads more disk bytes than the baseline;
* every replay completes every trace request;
* goodput-under-SLO ≤ raw goodput; attainment ∈ [0, 1].

    PYTHONPATH=src python -m benchmarks.slo_trace [--tiny]
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from benchmarks.common import write_bench_json  # noqa: F401  (src/ bootstrap)

EPS = 1e-9

# feature configs: name -> (warm tier on, prefix cache on)
CONFIGS = {
    "baseline": (False, False),
    "warm": (True, False),
    "prefix": (False, True),
    "warm_prefix": (True, True),
}

WARM_BUDGET = 1 << 20          # 1 MiB host-RAM warm tier when enabled


def build_model():
    import jax

    from repro.models.transformer import ModelConfig, init_params

    # Small enough to prefill on CPU in seconds, big enough that modeled
    # prefill compute (ORIN_NANO roofline) dominates a same-length restore
    # read — the regime where the prefix cache earns its keep.
    cfg = ModelConfig(name="slo-bench", arch_type="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=1, head_dim=16,
                      d_ff=1024, vocab_size=512)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def base_engine_cfg(max_seq: int):
    from repro.core.engine import EngineConfig

    # C < M keeps the reuse buffer undersized, so decode re-reads are real
    # and the warm tier has work to absorb; kv_bits=8 is the int8 disk tier
    # (and the warm tier's bit-exact regime).
    return EngineConfig(group_size=4, n_select=20, rank=16,
                        reuse_capacity=12, max_seq=max_seq, kv_bits=8,
                        predict_from="self", compute="jetson-orin-nano")


def make_session(cfg, params, calib, ecfg, *, slots, prefix_cache=None):
    from repro.models.transformer import TransformerAdapter
    from repro.serving.api import ServeSession

    return ServeSession(TransformerAdapter(cfg), params, ecfg, slots=slots,
                        calib_k=calib, prefix_cache=prefix_cache)


def run_cell(cfg, params, calib, ecfg, trace, *, disk, warm, prefix,
             slots) -> dict:
    """One sweep cell: fresh session (+ fresh prefix cache), one replay."""
    from repro.cache import PrefixCache, PrefixCacheConfig
    from repro.serving.trace import replay

    dcfg = dataclasses.replace(ecfg, disk=disk,
                               warm_budget_bytes=WARM_BUDGET if warm else 0)
    if prefix:
        # int8 slab: restore reads are 1/4 the raw-dtype size, matching the
        # kv_bits=8 disk tier
        with PrefixCache(PrefixCacheConfig(block_tokens=8, kv_bits=8)) as pc:
            with make_session(cfg, params, calib, dcfg, slots=slots,
                              prefix_cache=pc) as sess:
                return replay(trace, sess)
    with make_session(cfg, params, calib, dcfg, slots=slots) as sess:
        return replay(trace, sess)


def probe_service(cfg, params, calib, ecfg, *, prompt_tokens, max_new,
                  rng) -> dict:
    """Solo-request service profile on an idle session (ufs baseline):
    the time scale every SLO threshold and arrival gap derives from."""
    dcfg = dataclasses.replace(ecfg, disk="ufs")
    with make_session(cfg, params, calib, dcfg, slots=1) as sess:
        sess.submit(rng.integers(0, cfg.vocab_size, prompt_tokens), max_new)
        sess.drain()
        rec = sess.per_request()[0]
        return {"ttft_s": rec["ttft_seconds"], "tpot_s": rec["tpot_seconds"],
                "service_s": rec["e2e_seconds"]}


def sweep(out: dict, workload: str, trace, configs, disks, cfg, params,
          calib, ecfg, slots) -> None:
    cells = out["workloads"][workload] = {
        "n_requests": trace.n_requests, "trace_seed": trace.seed,
        "disks": {}}
    for disk in disks:
        cells["disks"][disk] = {}
        for name in configs:
            warm, prefix = CONFIGS[name]
            m = run_cell(cfg, params, calib, ecfg, trace, disk=disk,
                         warm=warm, prefix=prefix, slots=slots)
            del m["per_request"]   # bulky; the artifact keeps aggregates
            cells["disks"][disk][name] = m
            print(f"{workload},{disk},{name},"
                  f"{m['ttft']['p50'] * 1e3:.3f},{m['ttft']['p95'] * 1e3:.3f},"
                  f"{m['tpot']['p95'] * 1e3:.3f},{m['slo_attainment']:.2f},"
                  f"{m['goodput_under_slo_tokens_per_s']:.1f}")


def check_invariants(out: dict, chat_disks) -> list[str]:
    failures = []
    for wl, data in out["workloads"].items():
        for disk, cells in data["disks"].items():
            for name, m in cells.items():
                where = f"{wl}/{disk}/{name}"
                if m["requests"] != data["n_requests"]:
                    failures.append(f"{where}: completed {m['requests']} of "
                                    f"{data['n_requests']} requests")
                if m["goodput_under_slo_tokens_per_s"] > \
                        m["goodput_tokens_per_s"] + EPS:
                    failures.append(f"{where}: goodput-under-SLO exceeds "
                                    "raw goodput")
                for cls, b in m["slo"].items():
                    if not 0.0 <= b["attainment"] <= 1.0:
                        failures.append(f"{where}/{cls}: attainment "
                                        f"{b['attainment']} outside [0, 1]")
    chat = out["workloads"]["chat"]["disks"]
    for disk in chat_disks:
        base, wp = chat[disk]["baseline"], chat[disk]["warm_prefix"]
        if wp["ttft"]["p95"] > base["ttft"]["p95"] * (1 + EPS):
            failures.append(
                f"chat/{disk}: warm+prefix TTFT p95 "
                f"{wp['ttft']['p95']:.6f}s worse than baseline "
                f"{base['ttft']['p95']:.6f}s")
        if wp["engine"]["read_bytes"] > base["engine"]["read_bytes"]:
            failures.append(f"chat/{disk}: warm+prefix read more disk bytes "
                            "than baseline")
        if wp["cached_prompt_tokens"] <= 0:
            failures.append(f"chat/{disk}: prefix cache restored no tokens")
    speeds = [d for d in ("nvme", "ufs", "emmc") if d in chat]
    p50s = [chat[d]["baseline"]["ttft"]["p50"] for d in speeds]
    if sorted(p50s) != p50s:
        failures.append(f"chat baseline TTFT p50 not monotone across "
                        f"{speeds}: {p50s}")
    return failures


def main(tiny: bool = False) -> None:
    from repro.serving.metrics import SLOClass
    from repro.serving.trace import burst_trace, chat_trace, doc_trace

    cfg, params = build_model()
    rng = np.random.default_rng(0)
    calib = rng.standard_normal((256, cfg.n_kv_heads, cfg.head_dim)
                                ).astype(np.float32)
    slots = 2 if tiny else 3
    conversations, turns = (2, 3) if tiny else (4, 4)
    sys_tokens, user_tokens, chat_new = 112, 16, 12
    max_seq = 320
    ecfg = base_engine_cfg(max_seq)

    # -- calibrate SLO thresholds + arrival pacing on a ufs solo probe ----
    chat_prompt = sys_tokens + turns * user_tokens
    p_chat = probe_service(cfg, params, calib, ecfg, rng=rng,
                           prompt_tokens=chat_prompt, max_new=chat_new)
    p_doc = probe_service(cfg, params, calib, ecfg, rng=rng,
                          prompt_tokens=256, max_new=8)
    slo_classes = {
        "interactive": SLOClass("interactive", ttft_s=2.0 * p_chat["ttft_s"],
                                tpot_s=2.0 * p_chat["tpot_s"]),
        "batch": SLOClass("batch", ttft_s=3.0 * p_doc["ttft_s"],
                          tpot_s=3.0 * p_doc["tpot_s"]),
        "bulk": SLOClass("bulk", ttft_s=6.0 * p_chat["ttft_s"],
                         tpot_s=4.0 * p_chat["tpot_s"]),
    }

    # pace arrivals to ~80 % utilization of the slot pool at ufs baseline
    # speed: nvme runs underloaded, emmc overloaded — the spread the
    # per-disk attainment row exists to show
    turn_gap = p_chat["service_s"] * conversations / slots * 1.25
    chat = chat_trace(11, conversations=conversations, turns=turns,
                      sys_tokens=sys_tokens, user_tokens=user_tokens,
                      max_new=chat_new, turn_gap_s=turn_gap,
                      conv_gap_s=0.25 * turn_gap, slo_classes=slo_classes,
                      vocab_size=cfg.vocab_size)

    disks = ("nvme", "emmc") if tiny else ("nvme", "ufs", "emmc")
    configs = ("baseline", "warm_prefix") if tiny else tuple(CONFIGS)
    out = {
        "model": dataclasses.asdict(cfg),
        "engine": {"base": dataclasses.asdict(ecfg), "slots": slots,
                   "warm_budget_bytes": WARM_BUDGET},
        "slo_classes": {n: c.to_dict() for n, c in slo_classes.items()},
        "probe_ufs": {"chat": p_chat, "doc": p_doc},
        "workloads": {},
    }
    print("workload,disk,config,ttft_p50_ms,ttft_p95_ms,tpot_p95_ms,"
          "slo_attainment,goodput_under_slo_tok_s")
    sweep(out, "chat", chat, configs, disks, cfg, params, calib, ecfg, slots)

    if not tiny:
        doc = doc_trace(12, n_requests=8, doc_tokens=(192, 256), max_new=8,
                        interarrival_s=p_doc["service_s"] / slots * 1.25,
                        slo_classes=slo_classes, vocab_size=cfg.vocab_size)
        burst = burst_trace(13, bursts=4, burst_size=4,
                            quiet_s=p_chat["service_s"] * 4 / slots * 1.2,
                            within_s=0.1 * p_chat["service_s"],
                            prompt_tokens=(32, 48, 64),
                            max_new_choices=(6, 12),
                            slo_classes=slo_classes,
                            vocab_size=cfg.vocab_size)
        for wl, tr in (("doclong", doc), ("burst", burst)):
            sweep(out, wl, tr, ("baseline", "warm_prefix"), disks, cfg,
                  params, calib, ecfg, slots)

    failures = check_invariants(out, disks)
    out["invariants_ok"] = not failures
    write_bench_json("slo_trace", out, tiny=tiny)
    if failures:
        raise SystemExit("SLO invariants failed:\n  " + "\n  ".join(failures))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke mode: chat only, nvme+emmc, 2 configs")
    main(tiny=ap.parse_args().tiny)
