"""Paper Fig. 13b — number of selected entries MG vs throughput / accuracy.

MG↑ ⇒ recall rises with diminishing returns, throughput falls; MG=400 is the
paper's balanced default.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import LLAMA3_8B, Timer, correlated_kv, emit
from repro.core import baselines as B
from repro.core.offload import NVME, EMMC

HK, D, H = LLAMA3_8B.n_kv_heads, LLAMA3_8B.head_dim, LLAMA3_8B.n_heads


def run(mgs=(100, 200, 400, 800, 1600), n_ctx=4096) -> list[dict]:
    rng = np.random.default_rng(0)
    k, v = correlated_kv(rng, n_ctx, HK, D, true_rank=64)
    q = rng.standard_normal((H, D)).astype(np.float32)
    rows = []
    print("mg,disk,tokens_per_s,recall")
    for mg in mgs:
        rec = B.evaluate_policy(
            B.KVSwapPolicy(HK, D, group_size=4, rank=32, reuse=False),
            q, k, v, mg).recall
        for disk in (NVME, EMMC):
            pol = B.KVSwapPolicy(HK, D, group_size=4, rank=32, reuse=True)
            r = B.simulate_throughput(pol, disk=disk, dims=LLAMA3_8B, n_layers=32,
                                      batch=8, n_ctx=n_ctx, budget_tokens=mg, n_steps=6)
            rows.append({"mg": mg, "disk": disk.name, "tps": r["tokens_per_s"],
                         "recall": rec})
            print(f"{mg},{disk.name},{r['tokens_per_s']:.1f},{rec:.3f}")
    return rows


def main() -> str:
    with Timer() as t:
        rows = run()
    nv = {r["mg"]: r for r in rows if r["disk"] == "nvme"}
    ok = nv[1600]["tps"] < nv[100]["tps"] and nv[1600]["recall"] >= nv[100]["recall"]
    emit("fig13b_selection", t.us,
         f"tps_mg100={nv[100]['tps']:.1f} tps_mg1600={nv[1600]['tps']:.1f} trend_ok={ok}")
    return "ok"


if __name__ == "__main__":
    main()
