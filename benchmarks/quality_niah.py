"""Paper Tabs. 2/3 + Fig. 9 — generation-quality proxies.

Without the real checkpoints/datasets the offline container can't run RULER,
so this benchmark measures the *mechanism* the paper's quality numbers rest
on: does each method's selection keep the KV entries the true attention
needs?  Two metrics per method × budget:

* oracle-recall of the true top-budget tokens,
* relative L2 error of the sparse attention output,

plus a Fig. 9-style needle heatmap: is the group holding a planted
high-score needle selected, across (context length × depth)?
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, correlated_kv, emit
from repro.core import baselines as B

HK, D, H = 8, 128, 32


def methods(budget_frac_mem: float):
    """Comparable in-memory metadata: rank chosen to match the budget."""
    rank = max(4, int(budget_frac_mem * HK * D))
    return [
        B.InfiniGenPolicy(HK, D, partial_ratio=budget_frac_mem),
        B.InfiniGenPolicy(HK, D, partial_ratio=budget_frac_mem, head_agg=True),
        B.LokiPolicy(HK, D, rank=rank),
        B.ShadowKVPolicy(HK, D, rank=rank),
        B.KVSwapPolicy(HK, D, group_size=4, rank=rank, reuse=False),
    ]


def fidelity_table(n_ctx=2048, budget_tokens=400, seeds=4) -> list[dict]:
    rows = []
    print("setting,policy,recall,attn_mass,out_err")
    for frac, tag in ((1 / 8, "relaxed"), (1 / 32, "tight")):
        accum: dict = {}
        for seed in range(seeds):
            rng = np.random.default_rng(seed)
            k, v = correlated_kv(rng, n_ctx, HK, D, true_rank=64)
            q = rng.standard_normal((H, D)).astype(np.float32)
            for pol in methods(frac):
                pol.reset(n_ctx)
                r = B.evaluate_policy(pol, q, k, v, budget_tokens)
                a = accum.setdefault(pol.name, {"recall": [], "mass": [], "err": []})
                a["recall"].append(r.recall)
                a["mass"].append(r.mass)
                a["err"].append(r.out_err)
        for name, a in accum.items():
            rows.append({"setting": tag, "policy": name,
                         "recall": float(np.mean(a["recall"])),
                         "mass": float(np.mean(a["mass"])),
                         "out_err": float(np.mean(a["err"]))})
            print(f"{tag},{name},{np.mean(a['recall']):.3f},"
                  f"{np.mean(a['mass']):.3f},{np.mean(a['err']):.3f}")
    return rows


def needle_heatmap(ctxs=(1024, 2048, 4096), depths=(0.1, 0.3, 0.5, 0.7, 0.9),
                   budget_tokens=400) -> np.ndarray:
    """Fig. 9 analogue: 1.0 = needle group selected (model keeps capability)."""
    grid = np.zeros((len(depths), len(ctxs)))
    rng = np.random.default_rng(0)
    for ci, n in enumerate(ctxs):
        for di, depth in enumerate(depths):
            k, v = correlated_kv(rng, n, HK, D, true_rank=64)
            # plant a needle: keys aligned with the query's per-group mean,
            # scaled to clear the background score distribution (the NIAH
            # premise: the needle IS what the true attention retrieves)
            q = rng.standard_normal((H, D)).astype(np.float32)
            qg = q.reshape(HK, H // HK, D).mean(axis=1)
            bg = np.abs(B.head_scores(q, k).sum(0)).max()
            scale = 1.5 * bg / (np.linalg.norm(qg) ** 2 / HK * (H // HK))
            pos = int(depth * (n - 8))
            for j in range(8):
                k[pos + j] = scale * qg
            pol = B.KVSwapPolicy(HK, D, group_size=4, rank=64, reuse=False)
            sel = pol.select(q, k, budget_tokens)
            hit = len(set(range(pos, pos + 8)) & set(sel.token_ids.tolist())) > 0
            grid[di, ci] = float(hit)
    print("fig9_needle_grid (rows=depth, cols=ctx):")
    print(grid)
    return grid


def main() -> str:
    with Timer() as t:
        rows = fidelity_table()
        grid = needle_heatmap()
    tight = {r["policy"]: r for r in rows if r["setting"] == "tight"}
    emit("tab2_quality", t.us,
         f"tight_out_err kvswap={tight['kvswap']['out_err']:.3f} "
         f"shadowkv={tight['shadowkv']['out_err']:.3f} "
         f"infinigen={tight['infinigen']['out_err']:.3f} "
         f"needle_hit={grid.mean():.2f}")
    return "ok"


if __name__ == "__main__":
    main()
