"""Storage fault injection: graceful degradation across the tier stack.

Drives a real :class:`~repro.serving.api.ServeSession` (tiny model, greedy
sampling) through a fixed request trace under seeded
:class:`~repro.faults.plan.FaultPlan` campaigns, sweeping fault rate ×
disk class, and asserts the robustness contract (docs/robustness.md):

* **transient** faults (device read errors + short reads, burst below the
  retry budget) are absorbed by retry-with-backoff: every request's token
  stream is **bit-identical** to the fault-free run, no request fails, and
  zero prefetch worker threads die;
* **GC spikes** (emmc/ufs flash stalls) charge modeled time but change no
  bytes: tokens stay bit-identical while ``modeled_seconds`` and the
  accountant's ``stall_seconds`` lane grow;
* **persistent** faults (grown bad extents) are *bounded*: the session
  finishes the whole trace with the affected requests in the FAILED
  terminal state and every other request completed — never an uncaught
  exception, never a crashed session.

Usage::

    PYTHONPATH=src python -m benchmarks.fault_injection [--tiny]
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import write_bench_json

DISK_SWEEP = ("nvme", "ufs", "emmc")


def build_session(*, disk: str, faults=None, async_io: bool = True,
                  slots: int = 2, max_seq: int = 96):
    import jax

    from repro.core.engine import EngineConfig
    from repro.models.transformer import (ModelConfig, TransformerAdapter,
                                          init_params)
    from repro.serving.api import ServeSession

    cfg = ModelConfig(name="bench", arch_type="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=211)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    calib = rng.standard_normal((128, cfg.n_kv_heads, cfg.head_dim))
    ecfg = EngineConfig(group_size=4, n_select=6, rank=8, reuse_capacity=8,
                        max_seq=max_seq, predict_from="self", disk=disk,
                        async_io=async_io)
    return ServeSession(TransformerAdapter(cfg), params, ecfg, slots=slots,
                        calib_k=calib, faults=faults)


def run_trace(session, *, n_requests: int, prompt_len: int,
              max_new: int) -> dict:
    """Submit a fixed trace, drain, and flatten the outcome."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 211, prompt_len) for _ in range(n_requests)]
    rids = [session.submit(p, max_new=max_new, arrival=0.05 * i)
            for i, p in enumerate(prompts)]
    session.drain()
    stats = session.stats()
    eng = session.engine
    tokens = {rid: (session.completed[rid].output.tolist()
                    if rid in session.completed else None)
              for rid in rids}
    out = {
        "tokens": tokens,
        "completed": len(session.completed),
        "failed": len(session.failed),
        "failed_errors": {rid: r.error for rid, r in session.failed.items()},
        "modeled_seconds": stats["modeled_seconds"],
        "io_retries": stats["io_retries"],
        "fetch_failures": stats["fetch_failures"],
        "recovered_rows": stats["recovered_rows"],
        "stall_seconds": stats["stall_seconds"],
        "worker_deaths": (eng.prefetcher.deaths
                          if eng.prefetcher is not None else 0),
        "workers_alive": (eng.prefetcher.alive_threads()
                          if eng.prefetcher is not None else 0),
        "workers_total": (len(eng.prefetcher._threads)
                          if eng.prefetcher is not None else 0),
    }
    session.close()
    return out


def run_campaign(disk: str, *, tiny: bool) -> dict:
    from repro.faults import FaultPlan, FaultSpec

    n_requests = 3 if tiny else 6
    prompt_len = 24 if tiny else 40
    max_new = 4 if tiny else 8
    kw = dict(n_requests=n_requests, prompt_len=prompt_len, max_new=max_new)

    scenarios = {
        "baseline": None,
        "transient": FaultSpec(seed=3, read_error_rate=0.25,
                               torn_read_rate=0.15, error_burst=1),
        "spikes": FaultSpec(seed=5, spike_rate=0.5, spike_seconds=0.004),
        "persistent": FaultSpec(seed=11, bad_extent_rate=0.35),
    }
    out = {}
    for name, spec in scenarios.items():
        plan = None if spec is None else FaultPlan(spec)
        session = build_session(disk=disk, faults=plan)
        res = run_trace(session, **kw)
        if plan is not None:
            res["injected"] = plan.snapshot()
        out[name] = res

    base = out["baseline"]
    assert base["failed"] == 0, f"{disk}: fault-free run failed requests"

    # -- transient: retries make faults invisible except in the counters --
    tr = out["transient"]
    assert tr["tokens"] == base["tokens"], \
        f"{disk}: tokens diverged under transient faults"
    assert tr["failed"] == 0, f"{disk}: transient faults failed a request"
    assert tr["io_retries"] > 0, f"{disk}: transient campaign injected nothing"
    assert tr["worker_deaths"] == 0 and \
        tr["workers_alive"] == tr["workers_total"], \
        f"{disk}: prefetch workers died under transient faults"

    # -- spikes: time-only faults; fire only on flash disk classes --------
    sp = out["spikes"]
    assert sp["tokens"] == base["tokens"], \
        f"{disk}: tokens diverged under GC spikes"
    if disk in ("emmc", "ufs"):
        assert sp["stall_seconds"] > 0, f"{disk}: no spike ever charged"
        assert sp["modeled_seconds"] > base["modeled_seconds"], \
            f"{disk}: spikes did not slow the modeled clock"
    else:
        assert sp["stall_seconds"] == 0, f"{disk}: spike fired on nvme"

    # -- persistent: bounded degradation, never a crash -------------------
    pe = out["persistent"]
    assert pe["completed"] + pe["failed"] == n_requests, \
        f"{disk}: persistent campaign lost a request"
    for rid, toks in pe["tokens"].items():
        if toks is not None:
            assert toks == base["tokens"][rid], \
                f"{disk}: a *surviving* request's tokens diverged"
    assert pe["worker_deaths"] == 0, \
        f"{disk}: prefetch workers died under persistent faults"
    return out


def main(tiny: bool = False) -> None:
    payload = {}
    print("disk,scenario,completed,failed,retries,fetch_failures,"
          "recovered_rows,stall_ms,modeled_s")
    any_failed = 0
    for disk in DISK_SWEEP:
        payload[disk] = run_campaign(disk, tiny=tiny)
        for name, res in payload[disk].items():
            print(f"{disk},{name},{res['completed']},{res['failed']},"
                  f"{res['io_retries']},{res['fetch_failures']},"
                  f"{res['recovered_rows']},{res['stall_seconds'] * 1e3:.2f},"
                  f"{res['modeled_seconds']:.4f}")
            any_failed += res["failed"]
    # the persistent campaign must actually exercise the failure path on at
    # least one disk, or the sweep proves nothing
    assert any_failed > 0, "no persistent fault ever escalated; raise the rate"
    summary = {
        "disks": list(DISK_SWEEP),
        "transient_bit_identical": True,   # asserted per disk above
        "persistent_failed_requests": any_failed,
        "results": payload,
    }
    write_bench_json("fault_injection", summary, tiny=tiny)
    print("fault injection sweep: all robustness assertions held")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke mode: fewer/shorter requests")
    main(tiny=ap.parse_args().tiny)
