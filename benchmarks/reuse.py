"""Paper Tab. 5 — reuse ratio statistics + throughput uplift from reuse.

Runs the *real engine* (disk store + reuse buffer) on a trained tiny model to
measure reuse ratio, then the throughput model at paper scale for the
with/without-reuse uplift on both disks.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import LLAMA3_8B, Timer, emit
from repro.core import baselines as B
from repro.core.engine import EngineConfig, KVSwapEngine
from repro.core.offload import DISKS
from repro.models.transformer import ModelConfig, TransformerAdapter, init_params


def engine_reuse_ratio(n_inputs=4, n_steps=24) -> list[float]:
    cfg = ModelConfig(name="bench", arch_type="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=97)
    params = init_params(jax.random.PRNGKey(0), cfg)
    adapter = TransformerAdapter(cfg)
    rng = np.random.default_rng(0)
    calib = rng.standard_normal((256, cfg.n_kv_heads, cfg.head_dim))
    ratios = []
    for i in range(n_inputs):
        prompt = rng.integers(0, 97, (1, 48)).astype(np.int32)
        ecfg = EngineConfig(group_size=4, n_select=6, rank=8,
                            reuse_capacity=16, max_seq=128)
        with KVSwapEngine(adapter, params, ecfg, batch=1, calib_k=calib) as eng:
            eng.generate(prompt, n_steps)
            ratios.append(eng.reuse_ratio())
    return ratios


def throughput_uplift() -> dict:
    out = {}
    hk, d = LLAMA3_8B.n_kv_heads, LLAMA3_8B.head_dim
    for disk_name, disk in DISKS.items():
        tps = {}
        for reuse in (True, False):
            pol = B.KVSwapPolicy(hk, d, group_size=4, rank=32, reuse=reuse)
            r = B.simulate_throughput(pol, disk=disk, dims=LLAMA3_8B, n_layers=32,
                                      batch=8, n_ctx=4096, budget_tokens=400, n_steps=8)
            tps[reuse] = r["tokens_per_s"]
        out[disk_name] = tps[True] / tps[False]
    return out


def main() -> str:
    with Timer() as t:
        ratios = engine_reuse_ratio()
        uplift = throughput_uplift()
    print(f"reuse_ratio min={min(ratios):.3f} max={max(ratios):.3f} "
          f"avg={np.mean(ratios):.3f} std={np.std(ratios):.3f}")
    print(f"tp_uplift nvme={uplift['nvme']:.1f}x emmc={uplift['emmc']:.1f}x")
    emit("tab5_reuse", t.us,
         f"avg_reuse={np.mean(ratios):.2f} uplift_nvme={uplift['nvme']:.1f}x "
         f"uplift_emmc={uplift['emmc']:.1f}x")
    return "ok"


if __name__ == "__main__":
    main()
