"""Paper Fig. 12 — group size G vs throughput / accuracy / I/O utilization.

Reproduces the shape of the trade-off: G↑ ⇒ throughput and effective-BW
utilization rise (block-sized reads), oracle-recall drifts down (coarser
selection).  Reuse is DISABLED here, as in the paper's ablation.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import LLAMA3_8B, Timer, correlated_kv, emit
from repro.core import baselines as B
from repro.core.offload import DISKS

HK, D, H = LLAMA3_8B.n_kv_heads, LLAMA3_8B.head_dim, LLAMA3_8B.n_heads


def run(gs=(1, 2, 4, 8, 12, 16), budget=400, n_ctx=4096) -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    k, v = correlated_kv(rng, n_ctx, HK, D, true_rank=64)
    q = rng.standard_normal((H, D)).astype(np.float32)
    print("group_size,disk,tokens_per_s,recall,io_util")
    for g in gs:
        pol_q = B.KVSwapPolicy(HK, D, group_size=g, rank=32, reuse=False)
        rec = B.evaluate_policy(pol_q, q, k, v, budget).recall
        for disk_name, disk in DISKS.items():
            pol = B.KVSwapPolicy(HK, D, group_size=g, rank=32, reuse=False)
            r = B.simulate_throughput(pol, disk=disk, dims=LLAMA3_8B, n_layers=32,
                                      batch=8, n_ctx=n_ctx, budget_tokens=budget,
                                      n_steps=6)
            eff_bw = r["io_bytes_per_step"] / max(r["t_io"] / (32 * 8), 1e-12)
            util = min(1.0, eff_bw / disk.peak_bw)
            rows.append({"g": g, "disk": disk_name, "tps": r["tokens_per_s"],
                         "recall": rec, "util": util})
            print(f"{g},{disk_name},{r['tokens_per_s']:.1f},{rec:.3f},{util:.2f}")
    return rows


def main() -> str:
    with Timer() as t:
        rows = run()
    nvme = [r for r in rows if r["disk"] == "nvme"]
    tps_by_g = {r["g"]: r["tps"] for r in nvme}
    rec_by_g = {r["g"]: r["recall"] for r in nvme}
    # paper: throughput rises with G while accuracy degrades gradually
    ok = tps_by_g[8] > tps_by_g[1] and rec_by_g[16] <= rec_by_g[1] + 0.05
    emit("fig12_group_size", t.us,
         f"tps_g1={tps_by_g[1]:.1f} tps_g8={tps_by_g[8]:.1f} trend_ok={ok}")
    return "ok"


if __name__ == "__main__":
    main()
