"""Disaggregated prefill/decode vs co-located serving under a prefill burst.

The co-located :class:`~repro.serving.api.ServeSession` admits and decodes
on one modeled clock: a burst of long-document prefills lands *between*
the decode steps of running interactive requests, and every admission's
``modeled_seconds`` stretches their inter-token gaps — decode TPOT tails
absorb prefill compute.  Disaggregation
(:class:`~repro.disagg.DisaggFrontEnd`) moves prefill onto dedicated
engines whose clocks overlap the decode pool's by construction; the
decode session admits by **restoring the published chain** from the
shared :class:`~repro.cache.PrefixCache`, so an admission on the decode
clock costs a (planned, sequential) restore read instead of a full
prefill — the decode TPOT tail stays flat through the burst.

This harness replays one merged **doc-burst + chat** trace (the same
seed-deterministic requests, re-ridded by arrival) through

* ``solo``     — every request alone in a fresh one-slot session (the
  bit-identity reference),
* ``baseline`` — one co-located session (+ its own prefix cache, so the
  only delta vs disagg is *where* prefill runs),
* ``disagg``   — 2 prefill engines + 1 decode session over one shared
  cache,

for disk ∈ {nvme, ufs} (``--tiny``: nvme), all at ``kv_bits=16`` — the
restore-is-bit-identical regime, so every mode must emit the same tokens.

Asserted invariants (the run fails otherwise):

* **disagg decode TPOT p95 strictly better than co-located** on every
  disk (the headline);
* tokens bit-identical per request across solo / baseline / disagg;
* per-request warm-restore coverage: every disagg admission restored
  exactly the full published blocks of its prompt
  (``restored_tokens == ((S-1) // block_tokens) * block_tokens``);
* every trace request completes in every mode; no ticket failures, no
  re-prefills, no shed submissions.

    PYTHONPATH=src python -m benchmarks.disagg_serving [--tiny]
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from benchmarks.common import write_bench_json  # noqa: F401  (src/ bootstrap)

EPS = 1e-9
BLOCK_TOKENS = 32


def build_model():
    import jax

    from repro.models.transformer import ModelConfig, init_params

    # the slo_trace model: small enough to prefill on CPU in seconds, big
    # enough that modeled prefill compute (ORIN_NANO roofline) dominates a
    # same-length restore read — the regime disaggregation exploits
    cfg = ModelConfig(name="disagg-bench", arch_type="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=1, head_dim=16,
                      d_ff=1024, vocab_size=512)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def base_engine_cfg(max_seq: int):
    from repro.core.engine import EngineConfig

    # kv_bits=16: prefix restores are bit-identical to cold prefill, so
    # all three modes must agree token-for-token
    return EngineConfig(group_size=4, n_select=20, rank=16,
                        reuse_capacity=12, max_seq=max_seq, kv_bits=16,
                        predict_from="self", compute="jetson-orin-nano")


def merge_traces(name: str, *traces):
    """One trace from many: requests pooled, sorted by arrival, re-ridded.
    SLO classes are unioned (same-name classes must agree upstream)."""
    from repro.serving.trace import Trace

    classes, reqs = {}, []
    for tr in traces:
        classes.update(tr.slo_classes)
        reqs.extend(tr.requests)
    reqs.sort(key=lambda r: (r.arrival, r.rid))
    reqs = [dataclasses.replace(r, rid=i) for i, r in enumerate(reqs)]
    return Trace(workload=name, seed=traces[0].seed,
                 vocab_size=traces[0].vocab_size, slo_classes=classes,
                 requests=reqs)


def make_session(cfg, params, calib, ecfg, *, slots, prefix_cache=None):
    from repro.models.transformer import TransformerAdapter
    from repro.serving.api import ServeSession

    return ServeSession(TransformerAdapter(cfg), params, ecfg, slots=slots,
                        calib_k=calib, prefix_cache=prefix_cache)


def run_solo(cfg, params, calib, ecfg, trace) -> dict[int, list[int]]:
    """Every request alone in a fresh session: the reference tokens."""
    out = {}
    for r in trace.requests:
        with make_session(cfg, params, calib, ecfg, slots=1) as sess:
            rid = sess.submit(r.materialize(trace.vocab_size), r.max_new)
            sess.drain()
            out[r.rid] = sess.completed[rid].output.tolist()
    return out


def run_baseline(cfg, params, calib, ecfg, trace, *, slots) -> dict:
    """Co-located session with its own prefix cache (same cache policy as
    disagg — the only delta is where prefill runs)."""
    from repro.cache import PrefixCache, PrefixCacheConfig
    from repro.serving.trace import replay

    with PrefixCache(PrefixCacheConfig(block_tokens=BLOCK_TOKENS)) as pc:
        with make_session(cfg, params, calib, ecfg, slots=slots,
                          prefix_cache=pc) as sess:
            m = replay(trace, sess)
            m["tokens"] = {rid: req.output.tolist()
                           for rid, req in sess.completed.items()}
            return m


def run_disagg(cfg, params, calib, ecfg, trace, *, slots,
               n_prefill) -> dict:
    from repro.cache import PrefixCache, PrefixCacheConfig
    from repro.disagg import DisaggFrontEnd, PrefillEngine
    from repro.models.transformer import TransformerAdapter

    adapter = TransformerAdapter(cfg)
    with PrefixCache(PrefixCacheConfig(block_tokens=BLOCK_TOKENS)) as pc:
        prefills = [PrefillEngine(f"p{i}", adapter, params, ecfg, cache=pc,
                                  calib_k=calib) for i in range(n_prefill)]
        decode = make_session(cfg, params, calib, ecfg, slots=slots,
                              prefix_cache=pc)
        with DisaggFrontEnd(prefills, [decode], cache=pc) as front:
            m = front.replay(trace)
            m["tokens"] = {rid: out.tolist()
                           for rid, out in front.results().items()}
            return m


def check_invariants(out: dict, trace) -> list[str]:
    failures = []
    n = trace.n_requests
    prompt_tokens = {r.rid: r.prompt_tokens for r in trace.requests}
    solo = out["solo_tokens"]
    for disk, cell in out["disks"].items():
        base, dis = cell["baseline"], cell["disagg"]
        for mode, m in (("baseline", base), ("disagg", dis)):
            if m["requests"] != n:
                failures.append(f"{disk}/{mode}: completed {m['requests']} "
                                f"of {n} requests")
            for rid, toks in solo.items():
                got = m["tokens"].get(rid)
                if got != toks:
                    failures.append(f"{disk}/{mode}: request {rid} tokens "
                                    f"differ from solo reference")
                    break
        fleet = dis["fleet"]
        if fleet["ticket_failures"] or fleet["requeues"] \
                or fleet["handoff_rejections"]:
            failures.append(
                f"{disk}/disagg: unexpected fault-path activity "
                f"(failures={fleet['ticket_failures']}, "
                f"requeues={fleet['requeues']}, "
                f"shed={fleet['handoff_rejections']})")
        # the headline: decode TPOT p95 strictly better disaggregated
        if not dis["tpot"]["p95"] < base["tpot"]["p95"] - EPS:
            failures.append(
                f"{disk}: disagg TPOT p95 {dis['tpot']['p95']:.6f}s not "
                f"strictly better than co-located {base['tpot']['p95']:.6f}s")
        # per-request warm-restore coverage at the decode boundary
        for rec in dis["per_request"]:
            s = prompt_tokens[rec["rid"]]
            want = ((s - 1) // BLOCK_TOKENS) * BLOCK_TOKENS
            if rec["restored_tokens"] != want:
                failures.append(
                    f"{disk}/disagg: request {rec['rid']} restored "
                    f"{rec['restored_tokens']} of expected {want} tokens "
                    f"(prompt {s})")
    return failures


def main(tiny: bool = False) -> None:
    from repro.serving.metrics import SLOClass
    from repro.serving.trace import chat_trace, doc_trace

    cfg, params = build_model()
    rng = np.random.default_rng(0)
    calib = rng.standard_normal((256, cfg.n_kv_heads, cfg.head_dim)
                                ).astype(np.float32)
    slots = 2 if tiny else 3
    n_prefill = 2
    conversations, turns = (2, 2) if tiny else (3, 3)
    n_docs = 4 if tiny else 6
    sys_tokens, user_tokens, chat_new = 112, 16, 12
    ecfg = base_engine_cfg(max_seq=320)

    slo_classes = {
        "interactive": SLOClass("interactive", ttft_s=0.5, tpot_s=0.05),
        "batch": SLOClass("batch", ttft_s=2.0, tpot_s=0.1),
    }
    # chat turns paced so conversations overlap the doc burst; docs arrive
    # nearly back-to-back (the burst the co-located decode clock absorbs)
    chat = chat_trace(11, conversations=conversations, turns=turns,
                      sys_tokens=sys_tokens, user_tokens=user_tokens,
                      max_new=chat_new, turn_gap_s=0.02, conv_gap_s=0.005,
                      slo_classes=slo_classes, vocab_size=cfg.vocab_size)
    docs = doc_trace(12, n_requests=n_docs, doc_tokens=(240,), max_new=6,
                     interarrival_s=0.002, slo_classes=slo_classes,
                     vocab_size=cfg.vocab_size)
    # drop the burst into the middle of the chat phase
    mid = (chat.requests[-1].arrival if chat.requests else 0.0) * 0.3
    docs.requests = [dataclasses.replace(r, arrival=round(r.arrival + mid, 9))
                     for r in docs.requests]
    trace = merge_traces("docburst+chat", chat, docs)

    disks = ("nvme",) if tiny else ("nvme", "ufs")
    out = {
        "model": dataclasses.asdict(cfg),
        "engine": {"base": dataclasses.asdict(ecfg), "slots": slots,
                   "n_prefill": n_prefill, "block_tokens": BLOCK_TOKENS},
        "trace": {"workload": trace.workload, "n_requests": trace.n_requests,
                  "n_chat": chat.n_requests, "n_docs": len(docs.requests)},
        "disks": {},
    }
    print("disk,mode,tpot_p95_ms,tpot_p50_ms,ttft_p95_ms,makespan_s")
    # tokens depend only on prompt + sampling, never on the disk model, so
    # one solo pass (at the first disk) references every cell
    solo_ecfg = dataclasses.replace(ecfg, disk=disks[0])
    out["solo_tokens"] = run_solo(cfg, params, calib, solo_ecfg, trace)
    for disk in disks:
        dcfg = dataclasses.replace(ecfg, disk=disk)
        cell = out["disks"][disk] = {}
        for mode, run in (("baseline", lambda: run_baseline(
                               cfg, params, calib, dcfg, trace, slots=slots)),
                          ("disagg", lambda: run_disagg(
                               cfg, params, calib, dcfg, trace, slots=slots,
                               n_prefill=n_prefill))):
            m = run()
            cell[mode] = m
            makespan = (m["fleet"]["makespan_s"] if "fleet" in m
                        else m["makespan_seconds"])
            print(f"{disk},{mode},{m['tpot']['p95'] * 1e3:.3f},"
                  f"{m['tpot']['p50'] * 1e3:.3f},"
                  f"{m['ttft']['p95'] * 1e3:.3f},{makespan:.3f}")

    failures = check_invariants(out, trace)
    out["invariants_ok"] = not failures
    # the artifact keeps aggregates; tokens and per-request rows are bulky
    for cell in out["disks"].values():
        for m in cell.values():
            m.pop("tokens", None)
            m.pop("per_request", None)
    del out["solo_tokens"]
    write_bench_json("disagg_serving", out, tiny=tiny)
    if failures:
        raise SystemExit("disagg invariants failed:\n  "
                         + "\n  ".join(failures))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke mode: nvme only, smaller trace")
    main(tiny=ap.parse_args().tiny)
