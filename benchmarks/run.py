# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark aggregator — one entry per paper table/figure.

| entry              | paper artifact                  |
|--------------------|---------------------------------|
| tab4_throughput    | Tab. 4 / App. Tab. 2            |
| tab2_quality       | Tabs. 2/3 + Fig. 9 (NIAH)       |
| fig12_group_size   | Fig. 12 (G ablation)            |
| tab5_reuse         | Tab. 5 (reuse stats)            |
| fig13a_latency     | Fig. 13a (latency breakdown)    |
| fig13b_selection   | Fig. 13b (MG ablation)          |
| fig1_fig3a_memory  | Figs. 1 + 3a (memory)           |
| appA_tuner         | §3.5 / App. A (parameter tuner) |
| roofline           | §Roofline (from dry-run output) |
"""

import sys
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def main() -> None:
    from benchmarks import (ablation_group, ablation_selection, e2e_perplexity,
                            latency_breakdown, memory_footprint, quality_niah,
                            reuse, roofline, shardmap_ab, throughput,
                            tuner_demo)
    modules = [throughput, quality_niah, e2e_perplexity, ablation_group, reuse,
               latency_breakdown, ablation_selection, memory_footprint,
               tuner_demo, roofline, shardmap_ab]
    print("name,us_per_call,derived")
    failures = 0
    for mod in modules:
        try:
            mod.main()
        except Exception:  # noqa: BLE001 — keep the suite running
            failures += 1
            print(f"{mod.__name__},0,FAILED")
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
