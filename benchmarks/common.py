"""Shared helpers for the benchmark suite."""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

# allow `python -m benchmarks.run` from the repo root without install
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core.hardware import ModelDims

LLAMA3_8B = ModelDims(d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
                      d_ff=14336)
LLAMA3_3B = ModelDims(d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
                      d_ff=8192)
QWEN3_14B = ModelDims(d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
                      d_ff=13824)

N_LAYERS = {"llama3-8b": 32, "llama3-3b": 28, "qwen3-14b": 40}


def correlated_kv(rng, n, hk, d, *, rho=0.7, true_rank=None):
    """Token-correlated (optionally low-intrinsic-rank) synthetic K/V cache."""
    if true_rank:
        basis = rng.standard_normal((true_rank, hk * d))
        coef = np.empty((n, true_rank))
        prev = rng.standard_normal(true_rank)
        for t in range(n):
            prev = rho * prev + np.sqrt(1 - rho**2) * rng.standard_normal(true_rank)
            coef[t] = prev
        k = (coef @ basis).reshape(n, hk, d)
    else:
        k = np.empty((n, hk, d))
        prev = rng.standard_normal((hk, d))
        for t in range(n):
            prev = rho * prev + np.sqrt(1 - rho**2) * rng.standard_normal((hk, d))
            k[t] = prev
    v = rng.standard_normal((n, hk, d))
    return k.astype(np.float32), v.astype(np.float32)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.us = (time.perf_counter() - self.t0) * 1e6


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.0f},{derived}")


def write_bench_json(name: str, payload, *, tiny: bool = False,
                     path: str | Path | None = None, indent: int = 2) -> Path:
    """Write a benchmark's machine-readable artifact and return its path.

    The single naming convention for the suite: ``BENCH_{name}.json`` at the
    repo root, with a ``_tiny`` suffix when ``tiny=True`` so a CI smoke run
    never clobbers a committed full-run measurement.  ``path`` overrides the
    convention for artifacts whose location is derived from an input file
    (roofline).  Serialization matches the historical hand-rolled writers
    byte-for-byte: ``json.dumps(payload, indent=...)`` with no trailing
    newline.
    """
    if path is None:
        suffix = "_tiny" if tiny else ""
        path = Path(__file__).resolve().parent.parent / f"BENCH_{name}{suffix}.json"
    else:
        path = Path(path)
    path.write_text(json.dumps(payload, indent=indent))
    print(f"wrote {path.name}")
    return path
