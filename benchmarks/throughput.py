"""Paper Tab. 4 / App. Tab. 2 — decode throughput grid.

Replays the modeled Jetson+disk pipeline (DiskSpec + ComputeSpec + the
policies' real selection/I-O behaviour) across disk × batch × context-length,
for every offloading method.  The paper's MG=400 budget; per-batch KV budget
is the relaxed 1/13 setting.
"""

from __future__ import annotations


from benchmarks.common import LLAMA3_8B, Timer, emit
from repro.core import baselines as B
from repro.core.offload import DISKS


def policies(disk: str):
    hk, d = LLAMA3_8B.n_kv_heads, LLAMA3_8B.head_dim
    g = 8 if disk == "emmc" else 4
    return [
        B.FlexGenPolicy(hk, d),
        B.InfiniGenPolicy(hk, d),
        B.InfiniGenPolicy(hk, d, head_agg=True),
        B.InfiniGenPolicy(hk, d, head_agg=True, reuse=True),
        B.ShadowKVPolicy(hk, d, rank=160),
        B.ShadowKVPolicy(hk, d, rank=160, reuse=True),     # §7 "ShadowKV+reuse"
        B.LokiPolicy(hk, d, rank=32),
        B.KVSwapPolicy(hk, d, group_size=g, rank=32, reuse=True),
        B.KVSwapPolicy(hk, d, group_size=g, rank=32, reuse=True, kv_bytes=1),
    ]


def run(quick: bool = True) -> dict:
    batches = (1, 8) if quick else (1, 2, 4, 8, 16)
    cls = (16384, 32768) if quick else (8192, 16384, 24576, 32768)
    rows = []
    print("disk,policy,batch,context,tokens_per_s,io_ms,compute_ms")
    for disk_name, disk in DISKS.items():
        for cl in cls:
            for b in batches:
                for pol in policies(disk_name):
                    r = B.simulate_throughput(
                        pol, disk=disk, dims=LLAMA3_8B, n_layers=32, batch=b,
                        n_ctx=min(cl, 4096),  # selection trace length (I/O scales via budget)
                        budget_tokens=400, n_steps=8)
                    rows.append(dict(r, disk=disk_name, batch=b, context=cl))
                    print(f"{disk_name},{r['policy']},{b},{cl},"
                          f"{r['tokens_per_s']:.1f},{r['t_io']*1e3:.2f},{r['t_compute']*1e3:.2f}")
    return {"rows": rows}


def main() -> str:
    with Timer() as t:
        out = run(quick=True)
    rows = out["rows"]
    kv = [r for r in rows if r["policy"] == "kvswap" and r["disk"] == "nvme" and r["batch"] == 8]
    best = max(r["tokens_per_s"] for r in kv)
    emit("tab4_throughput", t.us, f"kvswap_nvme_b8={best:.1f}tok/s")
    return "ok"


if __name__ == "__main__":
    main()
