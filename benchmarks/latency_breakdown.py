"""Paper Fig. 13a — decoding latency breakdown of one transformer block.

Components: compute (attention+FFN+prediction), I/O (disk reads after reuse),
reuse-management overhead.  Methods ordered as in the figure: FlexGen →
InfiniGen* → InfiniGen*+reuse → ours w/o reuse → ours w/ reuse.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import LLAMA3_8B, Timer, emit
from repro.core import baselines as B
from repro.core.offload import NVME


def run(n_ctx=4096, budget=400, batch=8) -> dict:
    hk, d = LLAMA3_8B.n_kv_heads, LLAMA3_8B.head_dim
    methods = {
        "flexgen": B.FlexGenPolicy(hk, d),
        "infinigen*": B.InfiniGenPolicy(hk, d, head_agg=True),
        "infinigen*+reu": B.InfiniGenPolicy(hk, d, head_agg=True, reuse=True),
        "ours_wo_reu": B.KVSwapPolicy(hk, d, group_size=4, rank=32, reuse=False),
        "ours_w_reu": B.KVSwapPolicy(hk, d, group_size=4, rank=32, reuse=True),
    }
    rows = {}
    print("method,io_ms,compute_ms,reuse_mgmt_ms,total_ms")
    for name, pol in methods.items():
        r = B.simulate_throughput(pol, disk=NVME, dims=LLAMA3_8B, n_layers=1,
                                  batch=batch, n_ctx=n_ctx, budget_tokens=budget,
                                  n_steps=8)
        io_ms = r["t_io"] * 1e3
        c_ms = r["t_compute"] * 1e3
        mgmt = 0.1 if "reu" in name and "wo" not in name else 0.0  # slot-table upkeep (paper: ~1 ms / 32 blocks)
        rows[name] = {"io": io_ms, "compute": c_ms, "mgmt": mgmt,
                      "total": max(io_ms, c_ms) + mgmt}
        print(f"{name},{io_ms:.2f},{c_ms:.2f},{mgmt:.2f},{rows[name]['total']:.2f}")
    return rows


def main() -> str:
    with Timer() as t:
        rows = run()
    ratio = rows["flexgen"]["total"] / rows["ours_w_reu"]["total"]
    ok = (rows["ours_w_reu"]["total"] < rows["ours_wo_reu"]["total"]
          < rows["infinigen*"]["total"] < rows["flexgen"]["total"])
    emit("fig13a_latency", t.us, f"flexgen/ours={ratio:.1f}x ordering_ok={ok}")
    return "ok"


if __name__ == "__main__":
    main()
