"""Paper Fig. 13a — decoding latency breakdown of one transformer block.

Components: compute (attention+FFN+prediction), I/O (disk reads after reuse),
reuse-management overhead.  Methods ordered as in the figure: FlexGen →
InfiniGen* → InfiniGen*+reuse → ours w/o reuse → ours w/ reuse.

Second section (``run_engine_overlap``): the *real* engine, decoded through
the async prefetch pipeline (``repro.io``), reporting per-step modeled
``pipelined_seconds`` against the serial ``io_seconds + compute_seconds``
bound for both NVMe and eMMC device specs — the paper's §3.4 overlap claim,
measured on the actual runtime rather than the analytic policy simulator.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import LLAMA3_8B, Timer, emit
from repro.core import baselines as B
from repro.core.offload import NVME


def run(n_ctx=4096, budget=400, batch=8) -> dict:
    hk, d = LLAMA3_8B.n_kv_heads, LLAMA3_8B.head_dim
    methods = {
        "flexgen": B.FlexGenPolicy(hk, d),
        "infinigen*": B.InfiniGenPolicy(hk, d, head_agg=True),
        "infinigen*+reu": B.InfiniGenPolicy(hk, d, head_agg=True, reuse=True),
        "ours_wo_reu": B.KVSwapPolicy(hk, d, group_size=4, rank=32, reuse=False),
        "ours_w_reu": B.KVSwapPolicy(hk, d, group_size=4, rank=32, reuse=True),
    }
    rows = {}
    print("method,io_ms,compute_ms,reuse_mgmt_ms,total_ms")
    for name, pol in methods.items():
        r = B.simulate_throughput(pol, disk=NVME, dims=LLAMA3_8B, n_layers=1,
                                  batch=batch, n_ctx=n_ctx, budget_tokens=budget,
                                  n_steps=8)
        io_ms = r["t_io"] * 1e3
        c_ms = r["t_compute"] * 1e3
        mgmt = 0.1 if "reu" in name and "wo" not in name else 0.0  # slot-table upkeep (paper: ~1 ms / 32 blocks)
        rows[name] = {"io": io_ms, "compute": c_ms, "mgmt": mgmt,
                      "total": max(io_ms, c_ms) + mgmt}
        print(f"{name},{io_ms:.2f},{c_ms:.2f},{mgmt:.2f},{rows[name]['total']:.2f}")
    return rows


def run_engine_overlap(disk: str = "nvme", *, prompt_len=192, n_new=6,
                       n_layers=4, warm_budget=0) -> dict:
    """Decode a tiny model through the async engine; report per-step overlap.

    Returns mean modeled seconds and asserts nothing — callers check that
    ``pipelined < io + compute`` (strict, since every layer has compute and
    steady-state steps miss in the reuse buffer → non-zero interior I/O).
    """
    import jax

    from repro.core.engine import EngineConfig, KVSwapEngine
    from repro.models.transformer import (ModelConfig, TransformerAdapter,
                                          init_params)

    cfg = ModelConfig(name="bench-tiny", arch_type="dense", n_layers=n_layers,
                      d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128, vocab_size=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    model = TransformerAdapter(cfg)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, (2, prompt_len)).astype(np.int32)
    calib = rng.standard_normal((256, cfg.n_kv_heads, cfg.head_dim)).astype(np.float32)
    # small M + small C ⇒ every step pulls fresh groups from disk
    ecfg = EngineConfig(group_size=4, n_select=8, rank=8, reuse_capacity=8,
                        max_seq=256, disk=disk, predict_from="prev",
                        async_io=True, warm_budget_bytes=warm_budget)
    with KVSwapEngine(model, params, ecfg, batch=2, calib_k=calib) as eng:
        eng.generate(prompt, n_new)
        rep = eng.overlap_report()
        steps = eng.step_log[1:]
        src = eng.accountant.snapshot()["served_by_source"]
    served = src["disk"]["bytes"] + src["warm"]["bytes"]
    rep["warm_hit_rate"] = src["warm"]["bytes"] / served if served else 0.0
    warm_note = (f" warm_hit={rep['warm_hit_rate']:.1%}" if warm_budget else "")
    print(f"engine[{disk}]: io={rep['io_seconds']*1e3:.3f}ms "
          f"compute={rep['compute_seconds']*1e3:.3f}ms "
          f"pipelined={rep['pipelined_seconds']*1e3:.3f}ms "
          f"saved={rep['overlap_saved_seconds']*1e3:.3f}ms "
          f"io_wait_wall={rep['io_wait_seconds']*1e3:.2f}ms{warm_note}")
    rep["strict_overlap_all_steps"] = bool(steps) and all(
        s.pipelined_seconds < s.io_seconds + s.compute_seconds for s in steps)
    return rep


def main() -> str:
    with Timer() as t:
        rows = run()
        overlap = {d: run_engine_overlap(d) for d in ("nvme", "ufs", "emmc")}
        # warm-tier arm: same undersized-C regime with a host-RAM budget;
        # the accountant's per-source breakdown supplies the hit rate
        warm = run_engine_overlap("emmc", warm_budget=4 << 20)
    ratio = rows["flexgen"]["total"] / rows["ours_w_reu"]["total"]
    ok = (rows["ours_w_reu"]["total"] < rows["ours_wo_reu"]["total"]
          < rows["infinigen*"]["total"] < rows["flexgen"]["total"])
    pipelined_ok = all(r["strict_overlap_all_steps"] for r in overlap.values())
    emit("fig13a_latency", t.us,
         f"flexgen/ours={ratio:.1f}x ordering_ok={ok} "
         f"async_overlap_ok={pipelined_ok} "
         f"warm_hit_emmc={warm['warm_hit_rate']:.1%}")
    return "ok" if pipelined_ok else "overlap-violation"


if __name__ == "__main__":
    import sys
    sys.exit(0 if main() == "ok" else 1)
