"""Paper Fig. 1 + Fig. 3a — KV cache growth and management-memory comparison.

Fig. 1: full KV cache bytes vs context length × batch (Qwen3-4B-like dims).
Fig. 3a: in-memory management footprint of each method vs full-cache, for
LLaMA3-8B at batch 8 — KVSwap's compressed-K + buffers vs InfiniGen's
partial-K and ShadowKV's low-rank-K+landmarks.
"""

from __future__ import annotations


from benchmarks.common import LLAMA3_8B, Timer, emit
from repro.utils import GiB, fmt_bytes

FP16 = 2


def full_kv_bytes(n_layers, hk, d, batch, ctx, dtype_bytes=FP16):
    return n_layers * batch * ctx * 2 * hk * d * dtype_bytes


def fig1_kv_growth():
    # Qwen3-4B: 36 layers, 8 kv heads, d=128
    print("batch,context,kv_gib")
    rows = []
    for b in (1, 4, 8, 12):
        for ctx in (4096, 8192, 16384, 32768):
            kv = full_kv_bytes(36, 8, 128, b, ctx)
            rows.append((b, ctx, kv / GiB))
            print(f"{b},{ctx},{kv / GiB:.1f}")
    return rows


def fig3a_management_memory(batch=8):
    n_layers, hk, d = 32, LLAMA3_8B.n_kv_heads, LLAMA3_8B.head_dim
    feat = hk * d
    print("context,full_kv,infinigen,shadowkv,kvswap")
    rows = []
    for ctx in (4096, 8192, 16384, 32768):
        full = full_kv_bytes(n_layers, hk, d, batch, ctx)
        # InfiniGen: partial K (ratio 0.5) resident + speculation buffers
        infinigen = n_layers * batch * ctx * feat * FP16 * 0.5
        # ShadowKV: low-rank K (rank 160) + landmarks + staging (resident V loads)
        shadowkv = n_layers * batch * ctx * 160 * FP16 * 1.25
        # KVSwap: σ=32 compressed K + reuse (C=128 groups of 4) + rolling
        kvswap = (n_layers * batch * ctx * (feat // 32) * FP16
                  + n_layers * batch * 128 * 4 * 2 * feat * FP16
                  + n_layers * batch * 4 * 2 * feat * FP16)
        rows.append((ctx, full, infinigen, shadowkv, kvswap))
        print(f"{ctx},{fmt_bytes(full)},{fmt_bytes(infinigen)},"
              f"{fmt_bytes(shadowkv)},{fmt_bytes(kvswap)}")
    return rows


def main() -> str:
    with Timer() as t:
        fig1_kv_growth()
        rows = fig3a_management_memory()
    ctx32k = rows[-1]
    reduction = ctx32k[1] / ctx32k[4]
    emit("fig1_fig3a_memory", t.us,
         f"kv32k_b8={fmt_bytes(ctx32k[1])} kvswap_reduction={reduction:.0f}x")
    return "ok"


if __name__ == "__main__":
    main()
