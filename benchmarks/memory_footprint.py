"""Paper Fig. 1 + Fig. 3a — KV cache growth and management-memory comparison.

Fig. 1: full KV cache bytes vs context length × batch (Qwen3-4B-like dims).
Fig. 3a: in-memory management footprint of each method vs full-cache, for
LLaMA3-8B at batch 8 — KVSwap's compressed-K + buffers vs InfiniGen's
partial-K and ShadowKV's low-rank-K+landmarks.

Warm-tier audit: fills a real `repro.tiers.WarmTier` past its budget and
checks the accounting invariant the `warm_budget_bytes` knob promises —
resident slab bytes + per-entry index overhead never exceed the budget
(what `KVSwapEngine.metadata_bytes()` reports as `warm_tier` +
`warm_tier_index`).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import LLAMA3_8B, Timer, emit
from repro.tiers import WarmTier
from repro.utils import GiB, MiB, fmt_bytes

FP16 = 2


def full_kv_bytes(n_layers, hk, d, batch, ctx, dtype_bytes=FP16):
    return n_layers * batch * ctx * 2 * hk * d * dtype_bytes


def fig1_kv_growth():
    # Qwen3-4B: 36 layers, 8 kv heads, d=128
    print("batch,context,kv_gib")
    rows = []
    for b in (1, 4, 8, 12):
        for ctx in (4096, 8192, 16384, 32768):
            kv = full_kv_bytes(36, 8, 128, b, ctx)
            rows.append((b, ctx, kv / GiB))
            print(f"{b},{ctx},{kv / GiB:.1f}")
    return rows


def fig3a_management_memory(batch=8):
    n_layers, hk, d = 32, LLAMA3_8B.n_kv_heads, LLAMA3_8B.head_dim
    feat = hk * d
    print("context,full_kv,infinigen,shadowkv,kvswap")
    rows = []
    for ctx in (4096, 8192, 16384, 32768):
        full = full_kv_bytes(n_layers, hk, d, batch, ctx)
        # InfiniGen: partial K (ratio 0.5) resident + speculation buffers
        infinigen = n_layers * batch * ctx * feat * FP16 * 0.5
        # ShadowKV: low-rank K (rank 160) + landmarks + staging (resident V loads)
        shadowkv = n_layers * batch * ctx * 160 * FP16 * 1.25
        # KVSwap: σ=32 compressed K + reuse (C=128 groups of 4) + rolling
        kvswap = (n_layers * batch * ctx * (feat // 32) * FP16
                  + n_layers * batch * 128 * 4 * 2 * feat * FP16
                  + n_layers * batch * 4 * 2 * feat * FP16)
        rows.append((ctx, full, infinigen, shadowkv, kvswap))
        print(f"{ctx},{fmt_bytes(full)},{fmt_bytes(infinigen)},"
              f"{fmt_bytes(shadowkv)},{fmt_bytes(kvswap)}")
    return rows


def warm_tier_budget_audit(budget=16 * MiB, g=4):
    """Overfill a real warm tier and audit resident bytes against the knob."""
    hk, d = LLAMA3_8B.n_kv_heads, LLAMA3_8B.head_dim
    tier = WarmTier(budget_bytes=budget)
    rng = np.random.default_rng(0)
    group = rng.standard_normal((g, 2, hk, d)).astype(np.float32)
    per_entry = g * 2 * hk * d + 4  # int8 payload + scale
    n = budget // per_entry + 64    # deliberately past the budget
    for i in range(n):
        tier.admit(i % 32, i % 8, i, group)
    snap = tier.snapshot()
    resident = tier.nbytes + tier.index_nbytes
    print(f"warm_budget={fmt_bytes(budget)} slab={fmt_bytes(tier.nbytes)} "
          f"index={fmt_bytes(tier.index_nbytes)} resident={fmt_bytes(resident)} "
          f"entries={snap['entries']} evicted={snap['evicted']}")
    assert resident <= budget, "warm tier overran its budget"
    assert snap["evicted"] > 0, "audit never reached the eviction regime"
    return resident


def main() -> str:
    with Timer() as t:
        fig1_kv_growth()
        rows = fig3a_management_memory()
        warm_resident = warm_tier_budget_audit()
    ctx32k = rows[-1]
    reduction = ctx32k[1] / ctx32k[4]
    emit("fig1_fig3a_memory", t.us,
         f"kv32k_b8={fmt_bytes(ctx32k[1])} kvswap_reduction={reduction:.0f}x "
         f"warm_tier_resident={fmt_bytes(warm_resident)}")
    return "ok"


if __name__ == "__main__":
    main()
