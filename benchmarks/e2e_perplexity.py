"""End-to-end quality: teacher-forced perplexity through the real engine.

The policy-level quality bench (quality_niah) isolates selection fidelity;
this one closes the loop: a small LM trained on the synthetic stream is
evaluated teacher-forced, with every attention step served by the full
KVSwap runtime (disk store + prediction + reuse + rolling buffers), across
selection budgets — the Fig. 13b accuracy axis measured as perplexity.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit
from repro.core.engine import EngineConfig, KVSwapEngine
from repro.data import SyntheticLMStream
from repro.models.transformer import (ModelConfig, TransformerAdapter, forward,
                                      init_params)
from repro.training.optim import AdamWConfig, adamw_init
from repro.training.train import TrainState, make_train_step


def train_model(steps=120):
    cfg = ModelConfig(name="ppl", arch_type="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=97)
    params = init_params(jax.random.PRNGKey(0), cfg)
    stream = SyntheticLMStream(cfg.vocab_size, seed=21)
    step = make_train_step(forward, cfg, AdamWConfig(lr=3e-3), total_steps=steps)
    state = TrainState(params, adamw_init(params))
    for i in range(steps):
        b = stream.batch(i, 8, 32)
        state, _ = step(state, {k: jnp.asarray(v) for k, v in b.items()})
    return cfg, state.params, stream


def engine_xent(cfg, params, tokens, *, n_select, rank) -> float:
    """Teacher-forced token cross-entropy with attention served by KVSwap."""
    adapter = TransformerAdapter(cfg)
    b, s = tokens.shape
    prefix = 16
    rng = np.random.default_rng(0)
    calib = rng.standard_normal((256, cfg.n_kv_heads, cfg.head_dim))
    ecfg = EngineConfig(group_size=4, n_select=n_select, rank=rank,
                        reuse_capacity=2 * n_select, max_seq=s + 8,
                        predict_from="prev")
    lls = []
    with KVSwapEngine(adapter, params, ecfg, batch=b, calib_k=calib) as eng:
        logits = eng.prefill(tokens[:, :prefix])
        for t in range(prefix, s):
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            lls.append(np.asarray(jnp.take_along_axis(
                logp, jnp.asarray(tokens[:, t:t + 1]), -1))[:, 0])
            logits = eng.decode_step(tokens[:, t])
    return float(-np.mean(lls))


def full_xent(cfg, params, tokens, prefix=16) -> float:
    logits, _ = forward(params, cfg, jnp.asarray(tokens))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    ll = jnp.take_along_axis(logp[:, prefix - 1:-1], jnp.asarray(tokens[:, prefix:])[..., None], -1)
    return float(-ll.mean())


def main() -> str:
    with Timer() as t:
        cfg, params, stream = train_model()
        tokens = stream.batch(99_999, 4, 64)["tokens"]
        base = full_xent(cfg, params, tokens)
        print("budget,xent,ppl,delta_vs_full")
        print(f"full,{base:.4f},{np.exp(base):.2f},0.000")
        results = {}
        for n_sel, tag in ((16, "budget=64tok"), (8, "budget=32tok"), (4, "budget=16tok")):
            x = engine_xent(cfg, params, tokens, n_select=n_sel, rank=16)
            results[tag] = x - base
            print(f"{tag},{x:.4f},{np.exp(x):.2f},{x - base:+.4f}")
    emit("e2e_perplexity", t.us,
         " ".join(f"{k}:+{v:.3f}" for k, v in results.items()))
    return "ok"


if __name__ == "__main__":
    main()
