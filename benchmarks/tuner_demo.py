"""Paper §3.5 / App. A — offline parameter-tuning demonstration.

Runs the greedy solver for the paper's budgets and both disks; checks the
recovered settings against the paper's reported defaults (G=4 NVMe /
G=8-16 eMMC, MG=400, σ up to 32).
"""

from __future__ import annotations


from benchmarks.common import LLAMA3_8B, Timer, emit
from repro.core import tuner
from repro.utils import MiB


def run() -> dict:
    out = {}
    print("disk,budget,G,M,sigma,C,mem_mib,overlap")
    for disk in ("nvme", "emmc"):
        for budget, tag in ((310 * MiB, "relaxed"), (120 * MiB, "tight")):
            inp = tuner.TunerInputs(dims=LLAMA3_8B, n_layers=32, b_max=8,
                                    s_max=32768, budget_bytes=budget, disk=disk)
            t = tuner.solve(inp, reuse_table=tuner.build_reuse_table())
            out[f"{disk}_{tag}"] = t
            print(f"{disk},{tag},{t.group_size},{t.n_select},{t.sigma},"
                  f"{t.reuse_capacity},{t.mem_bytes / MiB:.0f},{t.meets_overlap}")
    return out


def main() -> str:
    with Timer() as t:
        out = run()
    nv = out["nvme_relaxed"]
    emit("appA_tuner", t.us,
         f"nvme_relaxed G={nv.group_size} sigma={nv.sigma} "
         f"in_budget={nv.mem_bytes <= 310 * MiB}")
    return "ok"


if __name__ == "__main__":
    main()
