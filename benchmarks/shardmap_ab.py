"""A/B: GSPMD-auto vs explicit shard_map sequence-parallel KVSwap attention.

Lowers the 32-layer long_500k attention stack (llama3-8b dims) both ways on
the 16×16 mesh and compares per-chip collective bytes — the explicit
flash-decoding combine moves only [B,H] partials per shard per layer.

    PYTHONPATH=src python -m benchmarks.shardmap_ab
"""

import os

if __name__ == "__main__":  # device count must be set before jax init
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from benchmarks.common import Timer, emit

B, H, HK, D, N, R, G, M, LAYERS = 1, 32, 8, 128, 524288, 64, 4, 100, 32


def build_inputs(mesh, seq_axes):
    kv_shard = NamedSharding(mesh, P(None, seq_axes, None, None))
    lr_shard = NamedSharding(mesh, P(None, seq_axes, None))
    rep = NamedSharding(mesh, P())
    sds = jax.ShapeDtypeStruct
    args = dict(
        q=sds((B, H, D), jnp.bfloat16),
        q_lr=sds((B, H, R), jnp.bfloat16),
        k_lr=sds((B, N, R), jnp.bfloat16),
        k=sds((B, N, HK, D), jnp.bfloat16),
        v=sds((B, N, HK, D), jnp.bfloat16),
        k_new=sds((B, HK, D), jnp.bfloat16),
        v_new=sds((B, HK, D), jnp.bfloat16),
        length=sds((), jnp.int32),
    )
    shards = dict(q=rep, q_lr=rep, k_lr=lr_shard, k=kv_shard, v=kv_shard,
                  k_new=rep, v_new=rep, length=rep)
    return args, shards


def gspmd_stack(q, q_lr, k_lr, k, v, k_new, v_new, length):
    """take_along_axis formulation; GSPMD chooses the collectives."""
    out = q
    for _ in range(LAYERS):
        scores = jnp.einsum("bhr,bnr->bn", q_lr, k_lr)
        pos = jnp.arange(N)
        scores = jnp.where((pos < length)[None], scores, -1e30)
        gsc = scores.reshape(B, N // G, G).max(-1)
        _, gids = jax.lax.top_k(gsc, M)
        tok = (gids[..., None] * G + jnp.arange(G)).reshape(B, -1)
        k_sel = jnp.take_along_axis(k, tok[..., None, None], axis=1)
        v_sel = jnp.take_along_axis(v, tok[..., None, None], axis=1)
        mask = tok < length
        from repro.models.layers import decode_attention
        out = out + decode_attention(q, k_sel, v_sel, mask, k_new, v_new)
    return out


def main() -> str:
    from repro.launch.dryrun import parse_collective_bytes
    from repro.launch.mesh import make_production_mesh
    from repro.serving.distributed import make_seqshard_decode_attn

    if len(jax.devices()) < 256:
        emit("shardmap_ab", 0, "SKIPPED (needs 512 forced host devices)")
        return "skipped"
    mesh = make_production_mesh()
    results = {}
    with Timer() as t:
        # A: GSPMD auto
        args, shards = build_inputs(mesh, ("data",))
        with mesh:
            comp = jax.jit(gspmd_stack, in_shardings=tuple(shards.values())) \
                .lower(*args.values()).compile()
        results["gspmd"] = parse_collective_bytes(comp.as_text())["total"]

        # B: explicit shard_map flash-decoding combine
        with mesh:
            attn = make_seqshard_decode_attn(mesh, axis="data", group_size=G,
                                             n_select=M, n_kv_heads=HK)

            def stack(q, q_lr, k_lr, k, v, k_new, v_new, length):
                out = q
                for _ in range(LAYERS):
                    out = out + attn(q, q_lr, k_lr, k, v, k_new, v_new, length)
                return out

            comp = jax.jit(stack, in_shardings=tuple(shards.values())) \
                .lower(*args.values()).compile()
        results["shard_map"] = parse_collective_bytes(comp.as_text())["total"]

    ratio = results["gspmd"] / max(results["shard_map"], 1)
    print(f"collective bytes/chip: gspmd={results['gspmd']:.3e} "
          f"shard_map={results['shard_map']:.3e} ({ratio:.1f}x)")
    emit("shardmap_ab", t.us,
         f"gspmd={results['gspmd']:.2e}B shard_map={results['shard_map']:.2e}B "
         f"reduction={ratio:.1f}x")
    return "ok"


if __name__ == "__main__":
    main()
