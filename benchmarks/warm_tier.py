"""A/B the host-RAM warm tier (`repro.tiers`) against the disk-only stack.

High re-read regime: the reuse buffer is deliberately undersized relative to
the per-step working set, so most steps evict groups that the very next
steps re-select — exactly the tail the warm tier exists to absorb.  For each
disk spec (nvme / ufs / emmc) the same prompt is decoded twice, warm tier
off (``warm_budget_bytes=0``) and on, with ``kv_bits=8`` so the on-disk and
warm formats match and decoded tokens are **bit-identical** between the two
arms (asserted).

Reported per arm:

* ``read_mb``        — disk bytes actually read (the number that must drop),
* ``warm_mb``        — bytes served by the warm tier instead (disk units),
* ``warm_hit_rate``  — fraction of reuse-buffer misses the tier absorbed,
* ``step_ms``        — median modeled per-step latency (pipelined; the
                       deterministic "step wall" on the modeled platform),
* ``wall_ms``        — measured host wall per step (reported, not gated:
                       container RAM serves both memmap and tier).

Checks (full mode): tokens identical per disk; disk read bytes strictly
lower with the tier on for **every** disk; median modeled step latency
strictly lower on nvme, ufs and emmc.  Emits ``BENCH_warm_tier.json``
(``--tiny`` writes ``BENCH_warm_tier_tiny.json`` and skips the asserts
except byte reduction).

Usage::

    PYTHONPATH=src python -m benchmarks.warm_tier [--tiny] [--steps N]
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import write_bench_json  # noqa: F401  (src/ bootstrap)

from repro.core.engine import EngineConfig, KVSwapEngine
from repro.models.transformer import ModelConfig, TransformerAdapter, init_params


def build_model(tiny: bool):
    if tiny:
        cfg = ModelConfig(name="warmtier-tiny", arch_type="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                          d_ff=128, vocab_size=128)
    else:
        cfg = ModelConfig(name="warmtier", arch_type="dense", n_layers=4,
                          d_model=128, n_heads=8, n_kv_heads=4, head_dim=16,
                          d_ff=256, vocab_size=512)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, TransformerAdapter(cfg), params


def run_one(adapter, params, prompt, calib, *, disk: str, warm_budget: int,
            steps: int, ecfg_kw: dict) -> tuple[np.ndarray, dict]:
    ecfg = EngineConfig(disk=disk, warm_budget_bytes=warm_budget, kv_bits=8,
                        **ecfg_kw)
    with KVSwapEngine(adapter, params, ecfg, batch=prompt.shape[0],
                      calib_k=calib) as eng:
        toks = eng.generate(prompt, steps)
        skip = min(ecfg.group_size + 2, max(1, steps - 2))
        log = eng.step_log[skip:]
        snap = eng.accountant.snapshot()
        warm = eng.warm.snapshot() if eng.warm is not None else None
        row = {
            "disk": disk,
            "warm_budget_bytes": warm_budget,
            "read_mb": snap["read_bytes"] / 1e6,
            "warm_mb": snap["warm_bytes"] / 1e6,
            "warm_hit_rate": warm["hit_rate"] if warm else 0.0,
            "step_ms": float(np.median(
                [s.pipelined_seconds for s in log])) * 1e3,
            "wall_ms": float(np.median([s.wall_seconds for s in log])) * 1e3,
            "reuse_hit_rate": eng.reuse_ratio(),
        }
    return toks, row


def main(tiny: bool = False, steps: int | None = None) -> dict:
    cfg, adapter, params = build_model(tiny)
    rng = np.random.default_rng(0)
    prompt_len = 96 if tiny else 512
    steps = steps or (10 if tiny else 24)
    batch = 2
    prompt = rng.integers(0, cfg.vocab_size, (batch, prompt_len)).astype(np.int32)
    calib = rng.standard_normal((512, cfg.n_kv_heads, cfg.head_dim)).astype(np.float32)
    ecfg_kw = dict(
        group_size=4,
        n_select=8 if tiny else 32,
        rank=16 if tiny else 32,
        # the high re-read regime: C below the per-step working set M, so
        # every step evicts groups the next steps re-select — the re-read
        # tail the warm tier absorbs (vs decode_hotpath's C >> M sizing)
        reuse_capacity=4 if tiny else 16,
        max_seq=256 if tiny else 1024,
    )
    budget = (1 << 20) if tiny else (8 << 20)
    disks = ["nvme"] if tiny else ["nvme", "ufs", "emmc"]

    rows = []
    print("disk,warm,read_mb,warm_mb,warm_hit_rate,step_ms,wall_ms")
    for disk in disks:
        arms = {}
        for wb in (0, budget):
            toks, row = run_one(adapter, params, prompt, calib, disk=disk,
                                warm_budget=wb, steps=steps, ecfg_kw=ecfg_kw)
            arms[wb] = (toks, row)
            rows.append(row)
            print(f"{disk},{bool(wb)},{row['read_mb']:.3f},{row['warm_mb']:.3f},"
                  f"{row['warm_hit_rate']:.3f},{row['step_ms']:.3f},"
                  f"{row['wall_ms']:.3f}")
        off, on = arms[0][1], arms[budget][1]
        assert np.array_equal(arms[0][0], arms[budget][0]), \
            f"warm-tier tokens diverged from the disk-only control ({disk})"
        assert on["read_mb"] < off["read_mb"], \
            f"warm tier did not reduce disk reads on {disk}"

    by_disk = {d: [r for r in rows if r["disk"] == d] for d in disks}
    summary = {}
    for d, (off, on) in by_disk.items():
        summary[d] = {
            "read_bytes_reduction": 1.0 - on["read_mb"] / max(off["read_mb"], 1e-12),
            "step_speedup": off["step_ms"] / max(on["step_ms"], 1e-12),
            "warm_hit_rate": on["warm_hit_rate"],
        }
        print(f"{d}: read_reduction={summary[d]['read_bytes_reduction']:.1%} "
              f"step_speedup={summary[d]['step_speedup']:.2f}x "
              f"warm_hit_rate={on['warm_hit_rate']:.1%}")

    out = {"model": cfg.name, "prompt_len": prompt_len, "steps": steps,
           "batch": batch, "engine": ecfg_kw, "warm_budget_bytes": budget,
           "kv_bits": 8, "results": rows, "summary": summary}
    write_bench_json("warm_tier", out, tiny=tiny)

    if not tiny:
        # the modeled median step latency is deterministic (DiskSpec +
        # ComputeSpec), so this gate is noise-free: serving re-reads from
        # host RAM must beat every modeled disk on the paper's platforms
        for d in disks:
            off, on = by_disk[d]
            assert on["step_ms"] < off["step_ms"], \
                (f"warm tier did not reduce the median modeled step on {d}: "
                 f"{on['step_ms']:.3f} >= {off['step_ms']:.3f} ms")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: nvme only, byte-reduction assert only")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    main(tiny=args.tiny, steps=args.steps)
